"""End-to-end driver: train a ~25M-param model from scratch on the synthetic
needle-retrieval task for a few hundred steps, then evaluate QUOKA vs dense
vs baselines on longer prompts — the in-repo NIAH experiment (paper §4.1).

    PYTHONPATH=src python examples/train_retrieval.py [--steps 400]
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.data.synthetic import needle_accuracy, needle_batch, needle_batches
from repro.models.model import build_model
from repro.training import checkpoint as ckpt
from repro.training import loop as train_loop
from repro.training import optimizer as opt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--ckpt", default="/tmp/retrieval_model.npz")
    args = ap.parse_args()

    cfg = get_config("llama3-2-3b").smoke(
        n_layers=args.layers, d_model=args.dim, n_heads=8, n_kv_heads=2,
        d_ff=args.dim * 3, vocab=512)
    cfg = dataclasses.replace(
        cfg, quoka=dataclasses.replace(cfg.quoka, chunk_size=64, budget=96,
                                       n_queries=8, keep_first=4))
    model = build_model(cfg)
    n_params = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(
        jax.eval_shape(model.init, jax.random.PRNGKey(0))))
    print(f"model: {args.layers}L d={args.dim} — {n_params/1e6:.1f}M params")

    gen = needle_batches(jax.random.PRNGKey(0), cfg.vocab, 16, 129,
                         n_keys=24)
    state, hist = train_loop.train(
        model, gen, steps=args.steps, log_every=50,
        ocfg=opt.OptimizerConfig(lr=3e-3, warmup_steps=30,
                                 total_steps=args.steps))
    ckpt.save(args.ckpt, state.params, {"steps": args.steps,
                                        "arch": cfg.name})
    print(f"checkpoint saved to {args.ckpt}")

    print("\nNIAH evaluation (retrieval accuracy):")
    rng = np.random.default_rng(1)
    print(f"{'len':>6s} {'depth':>6s} " + " ".join(
        f"{m:>12s}" for m in ("full", "quoka", "sample_attn", "sparq")))
    for t in (129, 257, 513):
        for depth in (0.2, 0.8):
            batch = needle_batch(rng, cfg.vocab, 16, t, n_keys=24,
                                 depth=depth)
            accs = [needle_accuracy(model, state.params, batch, m)
                    for m in ("full", "quoka", "sample_attention", "sparq")]
            print(f"{t:6d} {depth:6.1f} " +
                  " ".join(f"{a:12.2f}" for a in accs))


if __name__ == "__main__":
    main()
