"""Quickstart: QUOKA selection on a toy model in ~30 lines of public API.

Builds a reduced granite config, runs dense vs QUOKA chunked prefill, and
prints the selection quality metrics (output error vs the dense oracle, and
key-recall on the paper's Figure-2 query geometry).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import QuokaConfig
from repro.core.chunked_prefill import (chunked_sparse_attention,
                                        dense_causal_reference, key_recall,
                                        output_error)
from repro.data.synthetic import structured_qkv
from repro.models.model import build_model


def main():
    # --- 1. attention level: Algorithm 1+2 on structured Q/K/V ----------
    q, k, v = structured_qkv(jax.random.PRNGKey(0), b=2, t=1024, h=8,
                             n_kv=2, d=64)
    cfg = QuokaConfig(chunk_size=128, budget=128, n_queries=16, keep_first=4)
    print("attention level (T=1024, budget=128 => 12.5% of KVs):")
    for method in ("quoka", "sample_attention", "sparq"):
        err = float(output_error(q, k, v, cfg, method))
        rec = float(key_recall(q, k, v, cfg, method))
        print(f"  {method:18s} output_err={err:.4f}  key_recall={rec:.3f}")

    # --- 2. model level: chunked prefill through a real decoder ---------
    # (random-init models have DIFFUSE attention — the hardest case for any
    # selection; trained models concentrate, see examples/train_retrieval.py)
    import dataclasses
    mcfg = get_config("granite-3-2b").smoke()
    model = build_model(mcfg)
    params = model.init(jax.random.PRNGKey(1))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 128), 0,
                                mcfg.vocab)
    cache2 = model.init_cache(2, 160)
    logits_f, _ = model.prefill(params, {"tokens": tokens}, cache2, "full")
    print("\nmodel level: QUOKA-vs-dense last-token logit correlation on a"
          "\nrandom-init decoder (graceful degradation with budget):")
    cache = None
    for budget in (32, 64, 96):
        c = dataclasses.replace(mcfg, quoka=dataclasses.replace(
            mcfg.quoka, budget=budget))
        m2 = build_model(c)
        cache = m2.init_cache(2, 160)
        logits_q, cache = m2.prefill(params, {"tokens": tokens}, cache,
                                     "quoka")
        lq = logits_q - logits_q.mean(-1, keepdims=True)
        lf = logits_f - logits_f.mean(-1, keepdims=True)
        corr = float((lq * lf).sum() /
                     (jnp.linalg.norm(lq) * jnp.linalg.norm(lf)))
        print(f"  budget {budget:3d}/128 KVs: corr={corr:.3f}")
    tok, pos = jnp.argmax(logits_q, -1).astype(jnp.int32), 128
    step_logits, cache = model.decode_step(params, tok, pos, cache, "quoka")
    print(f"decode step OK, logits shape {step_logits.shape}")


if __name__ == "__main__":
    main()
