"""Serving demo: batched requests through the chunked-prefill engine with
QUOKA selection, reporting TTFT and decode throughput vs dense attention
(the paper's §4.6 measurement, CPU edition).

    PYTHONPATH=src python examples/serve_chunked.py [--prompt-len 1024]
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model import build_model
from repro.serving.engine import Engine
from repro.serving.sampler import SamplerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--prompt-len", type=int, default=1024)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke(n_layers=4, d_model=256, n_heads=8,
                                      n_kv_heads=2, d_ff=512, vocab=2048)
    cfg = dataclasses.replace(
        cfg, quoka=dataclasses.replace(cfg.quoka, chunk_size=128, budget=256,
                                       n_queries=16))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(3, cfg.vocab,
                                    (args.batch, args.prompt_len)), jnp.int32)

    print(f"{args.batch} requests × {args.prompt_len} tokens, "
          f"B_CP={cfg.quoka.chunk_size}, B_SA={cfg.quoka.budget}")
    results = {}
    for method in ("full", "quoka"):
        eng = Engine(model, params, method=method,
                     sampler=SamplerConfig(temperature=0.0))
        eng.generate({"tokens": toks}, 2)          # compile warmup
        r = eng.generate({"tokens": toks}, args.max_new)
        results[method] = r
        print(f"  {method:6s}: TTFT {r.ttft_s*1e3:8.1f} ms   "
              f"decode {r.decode_tps:7.1f} tok/s")
    sp = results["full"].ttft_s / results["quoka"].ttft_s
    print(f"QUOKA TTFT speedup: {sp:.2f}x "
          f"({100*cfg.quoka.budget/args.prompt_len:.0f}% budget)")
    if sp < 1.0:
        print("note: selection overhead exceeds savings for short prompts —"
              " the paper's regime starts around 8k tokens (try"
              " --prompt-len 2048+)")


if __name__ == "__main__":
    main()
