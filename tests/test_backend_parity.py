"""XLA vs Pallas(interpret) backend parity across the whole selection +
post-selection-attention path (the tentpole contract of the kernel facade:
every backend produces the same numbers within tolerance).

Shapes are deliberately GQA and RAGGED (T, budget and chunk sizes that are
not multiples of the kernel block sizes) so the kernel's internal padding
and per-KV-head `k_valid` handling are exercised.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import QuokaConfig
from repro.core import selection as sel_mod
from repro.core.attention import dense_attention
from repro.core.chunked_prefill import chunked_sparse_attention
from repro.core.quoka import quoka_scores, subselect_queries
from repro.kernels import ops as kops
from repro.models.model import build_model

@pytest.fixture(autouse=True)
def _no_env_backend(monkeypatch):
    """An exported REPRO_BACKEND outranks cfg.backend and would make every
    cfg-driven comparison here vacuous (same backend on both sides)."""
    monkeypatch.delenv("REPRO_BACKEND", raising=False)


KEY = jax.random.PRNGKey(11)
# ragged GQA geometry: T=192 (3 chunks of 64), budget 40, none of them
# multiples of the kernel's 128-lane blocks
B, T, H, NKV, D = 2, 192, 4, 2, 16
CHUNK, BUDGET = 64, 40


def _qkv(key=KEY, t=T):
    q = jax.random.normal(key, (B, t, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, t, NKV, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, t, NKV, D))
    return q, k, v


def _cfg(backend, **kw):
    base = dict(chunk_size=CHUNK, budget=BUDGET, n_queries=8, keep_first=2)
    base.update(kw)
    return QuokaConfig(backend=backend, **base)


# ---------------------------------------------------------------------------
# facade-level: boundary-prefix mask semantics vs dense_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("boundary,tq", [(40, 48), (13, 7), (0, 33)])
def test_attention_boundary_matches_dense_mask_semantics(boundary, tq):
    """ops.attention's [prefix | causal chunk] boundary mask must equal the
    legacy ad-hoc pattern: concat([k_valid prefix mask, tril], axis=-1)
    fed to dense_attention."""
    tk = boundary + tq
    q = jax.random.normal(KEY, (B, tq, H, D))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, tk, NKV, D))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, tk, NKV, D))
    prefix_ok = jax.random.bernoulli(jax.random.fold_in(KEY, 3), 0.7,
                                     (B, NKV, boundary))
    k_valid = jnp.concatenate([prefix_ok, jnp.ones((B, NKV, tq), bool)], -1)

    m_sel = jnp.broadcast_to(prefix_ok[:, :, None, :],
                             (B, NKV, tq, boundary))
    tri = jnp.broadcast_to(jnp.tril(jnp.ones((tq, tq), bool))[None, None],
                           (B, NKV, tq, tq))
    mask = jnp.concatenate([m_sel, tri], axis=-1)
    want = dense_attention(q, k, v, mask)

    for backend in ("xla", "pallas_interpret"):
        got = kops.attention(q, k, v, k_valid, causal=True,
                             boundary=boundary, backend=backend)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=1e-4,
                                   err_msg=f"backend={backend}")


def test_attention_backends_match_on_shared_valid():
    """(b, tk) shared k_valid keeps working (pre-facade call signature)."""
    q, k, v = _qkv(t=96)
    valid = jax.random.bernoulli(jax.random.fold_in(KEY, 9), 0.8, (B, 96))
    a = kops.attention(q, k, v, valid, causal=True, backend="xla")
    b_ = kops.attention(q, k, v, valid, causal=True,
                        backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                               atol=2e-5, rtol=1e-4)


def test_resolve_backend_priority(monkeypatch):
    cfg = QuokaConfig(backend="pallas_interpret")
    assert kops.resolve_backend("xla", cfg) == "xla"          # arg wins
    assert kops.resolve_backend(None, cfg) == "pallas_interpret"
    monkeypatch.setenv("REPRO_BACKEND", "xla")
    assert kops.resolve_backend(None, cfg) == "xla"           # env beats cfg
    monkeypatch.delenv("REPRO_BACKEND")
    assert kops.resolve_backend(None, None) in kops.BACKENDS  # hardware auto
    with pytest.raises(ValueError):
        kops.resolve_backend("cuda", None)


# ---------------------------------------------------------------------------
# scoring parity
# ---------------------------------------------------------------------------

def test_quoka_scores_backend_parity():
    q, k, _ = _qkv()
    qs = subselect_queries(q, 8, n_kv=NKV)
    valid = jnp.arange(T)[None].repeat(B, 0) < 100            # ragged valid
    s_x = quoka_scores(qs, k, valid, _cfg("xla"))
    s_p = quoka_scores(qs, k, valid, _cfg("pallas_interpret"))
    assert s_p.shape == (B, NKV, T)
    np.testing.assert_allclose(np.asarray(s_x), np.asarray(s_p),
                               atol=1e-4, rtol=1e-4)


def test_quoka_scores_ablation_arms_fall_back():
    """"dot"/"mean" ablations are outside the kernel contract: the kernel
    backend must silently use the einsum path, not crash or mis-score."""
    q, k, _ = _qkv()
    qs = subselect_queries(q, 8, n_kv=NKV)
    valid = jnp.ones((B, T), bool)
    for kw in (dict(scoring="dot"), dict(query_agg="mean")):
        s_x = quoka_scores(qs, k, valid, _cfg("xla", **kw))
        s_p = quoka_scores(qs, k, valid, _cfg("pallas_interpret", **kw))
        np.testing.assert_allclose(np.asarray(s_x), np.asarray(s_p),
                                   atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# chunked prefill parity — every selection method
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method",
                         [m for m in sel_mod.METHODS if m != "full"])
def test_chunked_sparse_attention_backend_parity(method):
    q, k, v = _qkv()
    out_x = chunked_sparse_attention(q, k, v, _cfg("xla"), method)
    out_p = chunked_sparse_attention(q, k, v, _cfg("pallas_interpret"),
                                     method)
    assert out_p.shape == (B, T, H, D)
    np.testing.assert_allclose(np.asarray(out_x), np.asarray(out_p),
                               atol=2e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# engine / model parity — the serving path really runs the kernels
# ---------------------------------------------------------------------------

def _smoke_model(arch="qwen3-4b", **q_over):
    cfg = get_config(arch).smoke(n_layers=2, d_model=64, n_heads=4,
                                 n_kv_heads=2, d_ff=128, vocab=128)
    qk = dict(chunk_size=16, budget=24, n_queries=4, keep_first=2)
    qk.update(q_over)
    cfg = dataclasses.replace(cfg, quoka=dataclasses.replace(cfg.quoka, **qk))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params, cfg


def test_model_prefill_backend_parity_and_kernel_use(monkeypatch):
    """model.prefill(backend="pallas_interpret") matches the XLA path AND
    traces through flash_attention_bhtd/quoka_score_bhtd (not the dense
    fallback)."""
    calls = {"attn": 0, "score": 0}
    real_fa, real_qs = kops.flash_attention_bhtd, kops.quoka_score_bhtd
    monkeypatch.setattr(
        kops, "flash_attention_bhtd",
        lambda *a, **k: (calls.__setitem__("attn", calls["attn"] + 1),
                         real_fa(*a, **k))[1])
    monkeypatch.setattr(
        kops, "quoka_score_bhtd",
        lambda *a, **k: (calls.__setitem__("score", calls["score"] + 1),
                         real_qs(*a, **k))[1])

    model, params, cfg = _smoke_model()
    toks = jnp.asarray(
        np.random.default_rng(0).integers(3, cfg.vocab, (2, 64)), jnp.int32)
    cache = model.init_cache(2, 80)
    lx, _ = model.prefill(params, {"tokens": toks}, cache, "quoka",
                          backend="xla")
    assert calls == {"attn": 0, "score": 0}
    cache = model.init_cache(2, 80)
    lp, _ = model.prefill(params, {"tokens": toks}, cache, "quoka",
                          backend="pallas_interpret")
    assert calls["attn"] > 0 and calls["score"] > 0
    np.testing.assert_allclose(np.asarray(lx), np.asarray(lp),
                               atol=5e-4, rtol=5e-3)


def test_engine_generate_backend_parity():
    from repro.serving.engine import Engine
    from repro.serving.sampler import SamplerConfig
    model, params, cfg = _smoke_model()
    toks = jnp.asarray(
        np.random.default_rng(1).integers(3, cfg.vocab, (2, 48)), jnp.int32)
    outs = {}
    for be in ("xla", "pallas_interpret"):
        eng = Engine(model, params, method="quoka", backend=be,
                     sampler=SamplerConfig(temperature=0.0))
        assert eng.backend == be
        r = eng.generate({"tokens": toks}, 3, key=jax.random.PRNGKey(5))
        assert r.backend == be
        outs[be] = r.tokens
    # greedy sampling: identical numerics within tolerance -> same tokens
    assert (outs["xla"] == outs["pallas_interpret"]).all()


def test_mla_prefill_backend_parity():
    """MLA's latent-space selected attention (zero-padded V trick) agrees
    across backends."""
    model, params, cfg = _smoke_model("deepseek-v3-671b")
    toks = jnp.asarray(
        np.random.default_rng(2).integers(3, cfg.vocab, (1, 64)), jnp.int32)
    cache = model.init_cache(1, 80)
    lx, _ = model.prefill(params, {"tokens": toks}, cache, "quoka",
                          backend="xla")
    cache = model.init_cache(1, 80)
    lp, _ = model.prefill(params, {"tokens": toks}, cache, "quoka",
                          backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(lx), np.asarray(lp),
                               atol=5e-4, rtol=5e-3)
