"""Smoke tests for the CLI launchers (train/serve/dryrun arg plumbing)."""
import os
import subprocess
import sys

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
ENV = {**os.environ, "PYTHONPATH": SRC}


def _run(args, timeout=420):
    return subprocess.run([sys.executable, "-m", *args], env=ENV,
                          capture_output=True, text=True, timeout=timeout)


def test_train_launcher_smoke():
    r = _run(["repro.launch.train", "--arch", "olmoe-1b-7b", "--smoke",
              "--steps", "3", "--batch", "2", "--seq", "64"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "loss" in r.stdout


def test_serve_launcher_smoke():
    r = _run(["repro.launch.serve", "--arch", "granite-3-2b", "--smoke",
              "--prompt-len", "128", "--batch", "2", "--max-new", "3",
              "--budget-ratio", "0.25"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "TTFT" in r.stdout


def test_dryrun_cases_enumeration():
    """The dry-run matrix covers 10 archs × shapes with the documented
    long_500k skips (34 combinations)."""
    from repro.launch.dryrun import LONG_OK, SHAPES, cases
    cs = list(cases())
    assert len(cs) == 34
    archs = {a for a, _ in cs}
    assert len(archs) == 10
    for a, s in cs:
        if s == "long_500k":
            assert a in LONG_OK
