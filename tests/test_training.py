"""Optimizer / schedule / checkpoint correctness."""
import os

import pytest

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model import build_model
from repro.training import checkpoint as ckpt
from repro.training import loop as train_loop
from repro.training import optimizer as opt

KEY = jax.random.PRNGKey(0)


def test_adamw_quadratic_converges():
    """AdamW on f(w) = ||w - target||^2 reaches the target."""
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    ocfg = opt.OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=400,
                               weight_decay=0.0, clip_norm=None)
    state = opt.init(params)
    for _ in range(400):
        g = {"w": 2 * (params["w"] - target)}
        params, state, _ = opt.apply_updates(params, g, state, ocfg)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_adamw_first_step_magnitude():
    """First AdamW step moves every coordinate by exactly the scheduled lr
    (bias-corrected m/sqrt(v) = sign(g) on step one)."""
    params = {"w": jnp.zeros(4)}
    ocfg = opt.OptimizerConfig(lr=0.5, warmup_steps=0, total_steps=10,
                               weight_decay=0.0, clip_norm=None)
    state = opt.init(params)
    g = {"w": jnp.asarray([1.0, -1.0, 2.0, -0.5])}
    p2, _, m = opt.apply_updates(params, g, state, ocfg)
    lr1 = float(opt.schedule(ocfg, 1))
    np.testing.assert_allclose(np.abs(np.asarray(p2["w"])), lr1, rtol=1e-3)
    assert np.sign(np.asarray(p2["w"])).tolist() == [-1, 1, -1, 1]


def test_grad_clipping():
    params = {"w": jnp.zeros(3)}
    ocfg = opt.OptimizerConfig(lr=1.0, warmup_steps=0, total_steps=10,
                               clip_norm=1.0, weight_decay=0.0)
    state = opt.init(params)
    g = {"w": jnp.asarray([300.0, 400.0, 0.0])}   # norm 500
    _, _, m = opt.apply_updates(params, g, state, ocfg)
    assert abs(float(m["grad_norm"]) - 500.0) < 1e-3


def test_schedule_shape():
    ocfg = opt.OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                               min_lr_ratio=0.1)
    s = [float(opt.schedule(ocfg, i)) for i in range(0, 101, 10)]
    assert s[0] == 0.0
    assert abs(s[1] - 1e-3) < 1e-9          # end of warmup
    assert s[-1] <= 1.1e-4 + 1e-9           # decayed to min ratio
    assert all(a >= b - 1e-12 for a, b in zip(s[1:], s[2:]))  # monotone decay


def test_weight_decay_only_on_matrices():
    params = {"w": jnp.ones((2, 2)), "g": jnp.ones((4,))}
    ocfg = opt.OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=10,
                               weight_decay=1.0, clip_norm=None)
    state = opt.init(params)
    zeros = {"w": jnp.zeros((2, 2)), "g": jnp.zeros((4,))}
    p2, _, _ = opt.apply_updates(params, zeros, state, ocfg)
    assert float(p2["w"][0, 0]) < 1.0       # decayed
    assert float(p2["g"][0]) == 1.0         # not decayed


@pytest.mark.slow
def test_training_reduces_loss_on_retrieval_data():
    from repro.data.synthetic import needle_batches
    cfg = get_config("granite-3-2b").smoke(n_layers=2, d_model=128,
                                           d_ff=256, vocab=128)
    model = build_model(cfg)
    gen = needle_batches(KEY, cfg.vocab, 16, 65, n_keys=16)
    state, hist = train_loop.train(
        model, gen, steps=120, log_every=40,
        ocfg=opt.OptimizerConfig(lr=3e-3, warmup_steps=10, total_steps=120))
    assert hist[-1][1] < hist[0][1] - 0.3, hist


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("olmoe-1b-7b").smoke()
    model = build_model(cfg)
    state = train_loop.init_state(model, KEY)
    path = os.path.join(tmp_path, "ck.npz")
    ckpt.save(path, state, {"step": 0})
    state2 = ckpt.restore(path, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(state2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ckpt.load_meta(path)["step"] == 0


def test_checkpoint_mismatch_raises(tmp_path):
    path = os.path.join(tmp_path, "ck.npz")
    ckpt.save(path, {"a": jnp.ones(3)})
    try:
        ckpt.restore(path, {"b": jnp.ones(3)})
        raise AssertionError("should have raised")
    except ValueError:
        pass
