"""Hierarchical KV pool (host-memory tier behind the device pool).

Eviction pressure on a pool with ``host_tier_blocks`` > 0 DEMOTES
registered prefix blocks to host buffers instead of destroying them; the
scheduler matches both tiers, and admission PROMOTES host matches back
into fresh device blocks (serving/pool.py).  The gate in every test here
is the same one the device-side prefix cache answers to: tiering must be
invisible to outputs.  A device pool sized so that every finished
request's blocks are evicted before the trace repeats must still serve
token-identically to an unconstrained pool — the host tier only changes
WHERE the cached KV waits, never what attention reads.

Also covered: host-tier slot/LRU/refcount invariants under randomized
pressure, the data round-trip of a demote -> match -> promote cycle at
the pool level, the selection-score-driven H2D prefetch overlapping
engine steps (obs spans), and the regression gate's ungated-record
warning (benchmarks/check_regression.py).

The suite carries the ``offload`` marker: CI runs it as the fast tier's
dedicated offload-smoke step (``pytest -m offload``).
"""
import json
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import build_model
from repro.serving import request as rq
from repro.serving.engine import Engine
from repro.serving.pool import PagedKVCache, blocks_for_request
from repro.serving.request import make_requests
from repro.serving.scheduler import Scheduler

pytestmark = pytest.mark.offload

KEY = jax.random.PRNGKey(0)
BS = 16


@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_config("granite-3-2b").smoke()
    model = build_model(cfg)
    return cfg, model, model.init(KEY)


# ---------------------------------------------------------------------------
# pool level: demote -> match -> promote round trip
# ---------------------------------------------------------------------------

def _fill(data, blocks, seed=5):
    """Plant recognizable per-block content (distinct value per block) in
    every KV leaf so a tier round trip can be checked for data equality."""
    def f(leaf):
        if leaf.ndim < 3:
            return leaf
        for j, b in enumerate(blocks):
            val = seed + j
            if not jnp.issubdtype(leaf.dtype, jnp.integer):
                val = (seed + j) * 0.25
            leaf = leaf.at[:, b].set(val)
        return leaf
    return jax.tree.map(f, data)


def _snap(data, blocks):
    return [np.asarray(leaf[:, np.asarray(blocks)])
            for leaf in jax.tree.leaves(data)
            if hasattr(leaf, "ndim") and leaf.ndim >= 3]


def test_demote_match_promote_roundtrip(smoke_model):
    """Pressure-evicting a registered prefix moves its KV to the host tier
    (matchable as ("host", slot) entries); alloc_prefix promotes it into
    fresh device blocks carrying bit-identical content."""
    _, model, _ = smoke_model
    pool = PagedKVCache(model, num_blocks=3, block_size=BS,
                        host_tier_blocks=4)
    toks = np.arange(2 * BS, dtype=np.int32) + 3
    pool.alloc(0, 2)
    donor = pool.table(0)
    pool.data = _fill(pool.data, donor)
    pool.register_prefix(0, toks)
    want = _snap(pool.data, donor)
    pool.free(0)                                # both blocks on the LRU
    pool.alloc(1, 3)                            # pressure: evicts -> demotes
    assert pool.demoted == 2
    fulls, tail = pool.match_prefix(toks)
    assert [b for b in fulls if not isinstance(b, tuple)] == []
    assert len(fulls) == 2 and tail is None
    pool.check_invariants()
    pool.free(1)
    table = pool.alloc_prefix(2, 3, shared=fulls)
    assert pool.promoted == 2
    got = _snap(pool.data, table[:2])
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)
    # single residency: the hash now lives on the device tier again
    fulls2, _ = pool.match_prefix(toks)
    assert fulls2 == table[:2]
    pool.check_invariants()


def test_host_tier_randomized_invariants(smoke_model):
    """Randomized admit/free cycles over a tiny device pool + tinier host
    tier: slot maps, LRU order, hash indexes and cross-tier single
    residency stay consistent while demotion, promotion and host-side
    eviction (cache LOSS at the bottom of the hierarchy) all trigger."""
    _, model, _ = smoke_model
    pool = PagedKVCache(model, num_blocks=8, block_size=BS,
                        host_tier_blocks=3)
    sched = Scheduler(pool, chunk_size=BS, max_prefill_tokens=BS,
                      max_decode_batch=8, prefix_cache=True, prefix_align=1)
    rng = np.random.default_rng(1)
    fams = [rng.integers(3, 100, (3 * BS,)).astype(np.int32)
            for _ in range(3)]
    held = {}
    rid = 0
    for _ in range(200):
        if held and (rng.random() < 0.5 or not pool.can_alloc(4)):
            victim = int(rng.choice(list(held)))
            pool.free(victim)
            del held[victim]
        else:
            fam = fams[int(rng.integers(len(fams)))]
            plen = int(rng.integers(BS, len(fam)))
            toks = fam[:plen].copy()
            r = rq.Request(rid=rid, tokens=toks, max_new=1)
            cached, shared, cow = sched._match(r)
            dev_shared = [b for b in shared if not isinstance(b, tuple)]
            protect = dev_shared + \
                ([cow[0]] if cow and not isinstance(cow[0], tuple) else [])
            n = blocks_for_request(plen, 1, BS, BS, cached_len=cached)
            if pool.can_alloc(n - len(dev_shared), exclude=protect):
                pool.alloc_prefix(rid, n, shared, cow)
                pool.register_prefix(rid, toks)
                held[rid] = True
                rid += 1
        pool.check_invariants()
    assert pool.demoted > 0                     # pressure reached the tier
    assert pool.promoted > 0                    # host matches re-admitted
    assert pool.host_evictions > 0              # and the tier itself filled
    for r_ in list(held):
        pool.free(r_)
    pool.check_invariants()


# ---------------------------------------------------------------------------
# end-to-end: tiering is invisible to outputs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["full", "quoka"])
def test_undersized_pool_with_host_tier_parity(smoke_model, method):
    """The acceptance gate: a device pool sized below the trace's working
    set (every finished request's prefix blocks are evicted before the
    re-send) + host tier serves token-identically to an unconstrained
    big-pool serve and to cold per-request generate(), on BOTH the cold
    pass and the prefix-hit re-send — with the tier actually exercised
    (demotions on pass 1, promotions on pass 2)."""
    cfg, model, p = smoke_model
    eng = Engine(model, p, method=method)
    rng = np.random.default_rng(21)
    prompts = [rng.integers(3, cfg.vocab, (2 * BS,)).astype(np.int32)
               for _ in range(4)]
    max_new = 4
    refs = [eng.generate(eng.pad_prompt(pr[None]), max_new).tokens[0]
            for pr in prompts]
    big = eng.make_serve_state(make_requests(prompts, max_new),
                               block_size=BS, max_decode_batch=1)
    big_res = eng.serve(make_requests(prompts, max_new), state=big)
    need = blocks_for_request(2 * BS, max_new, BS, BS)
    state = eng.make_serve_state(make_requests(prompts, max_new),
                                 block_size=BS, num_blocks=need + 1,
                                 max_decode_batch=1,
                                 host_tier_blocks=4 * need)
    cold = eng.serve(make_requests(prompts, max_new), state=state)
    assert eng.stats["demoted"] > 0
    for i, ref in enumerate(refs):
        np.testing.assert_array_equal(cold.tokens[i], ref)
        np.testing.assert_array_equal(big_res.tokens[i], ref)
    hot = eng.serve(make_requests(prompts, max_new), state=state)
    assert eng.stats["promoted"] > 0
    assert eng.stats["cache_hits"] > 0
    assert any(v > 0 for v in hot.cached_len.values())
    if method != "full":                        # hits stay on the B_CP grid
        assert all(v % cfg.quoka.chunk_size == 0
                   for v in hot.cached_len.values())
    for i, ref in enumerate(refs):
        np.testing.assert_array_equal(hot.tokens[i], ref)
    state.pool.check_invariants()


def test_prefetch_overlaps_engine_steps(smoke_model):
    """The selection-score-driven prefetch stages H2D copies for the next
    waiting request's host matches WHILE the current request's step runs:
    every pool/h2d_stage span must nest inside an engine step span, and at
    least one promotion must consume a staged buffer instead of issuing a
    blocking copy at admission."""
    from repro.obs import Registry
    cfg, model, p = smoke_model
    reg = Registry()
    eng = Engine(model, p, method="quoka", registry=reg)
    rng = np.random.default_rng(23)
    x = rng.integers(3, cfg.vocab, (2 * BS,)).astype(np.int32)
    y = rng.integers(3, cfg.vocab, (2 * BS,)).astype(np.int32)
    need = blocks_for_request(2 * BS, 4, BS, BS)
    state = eng.make_serve_state(make_requests([x], 4), block_size=BS,
                                 num_blocks=need + 1, max_decode_batch=1,
                                 host_tier_blocks=4 * need,
                                 prefetch_depth=4)
    eng.serve(make_requests([x], 4), state=state)   # register x
    eng.serve(make_requests([y], 4), state=state)   # pressure demotes x
    # x queues behind y (max_decode_batch=1): its host blocks are staged
    # during y's steps and consumed when x is admitted
    res = eng.serve(make_requests([y, x], 4), state=state)
    assert eng.stats["staged_used"] >= 1
    snap = reg.snapshot()
    assert snap["counters"].get("pool/staged", 0) >= 1
    stage = [e for e in reg.trace_events if e["name"] == "pool/h2d_stage"]
    steps = [e for e in reg.trace_events
             if e["name"] in ("engine/prefill_step", "engine/decode_step")]
    assert stage, "prefetch never staged a host block"
    for e in stage:
        assert any(s["ts"] <= e["ts"] and
                   e["ts"] + e["dur"] <= s["ts"] + s["dur"] for s in steps), \
            "h2d_stage span not nested inside an engine step span"
    assert len(res.tokens[1]) == 4          # x finished through the cycle
    state.pool.check_invariants()


def test_host_tier_rejects_mesh(smoke_model):
    """The host tier is single-device (per-buffer device_put round trips
    don't compose with sharded pool leaves yet) — constructing a sharded
    pool with host_tier_blocks must fail loudly."""
    _, model, _ = smoke_model

    class FakeMesh:               # pool only checks `mesh is not None`-ness
        pass

    with pytest.raises(ValueError, match="host"):
        PagedKVCache(model, num_blocks=4, block_size=BS,
                     mesh=FakeMesh(), host_tier_blocks=4)


# ---------------------------------------------------------------------------
# regression-gate plumbing (benchmarks/check_regression.py)
# ---------------------------------------------------------------------------

def test_check_regression_warns_on_ungated_records(tmp_path, monkeypatch,
                                                   capsys):
    """Records no baseline metric selects used to pass silently; the gate
    now surfaces them as ::warning annotations and writes the per-metric
    table to $GITHUB_STEP_SUMMARY."""
    check_regression = pytest.importorskip("benchmarks.check_regression")
    out, base = tmp_path / "out", tmp_path / "baselines"
    out.mkdir(), base.mkdir()
    (out / "mybench.json").write_text(json.dumps([
        {"name": "my/gated", "us_per_call": 1.0, "scenario": "a",
         "speed": 2.0},
        {"name": "my/loose", "us_per_call": 1.0, "scenario": "b"},
    ]))
    (base / "mybench.json").write_text(json.dumps({"metrics": [
        {"name": "gated_speed", "match": {"scenario": "a"}, "field": "speed",
         "baseline": 2.0, "rel_tol": 0.5},
    ]}))
    summary = tmp_path / "summary.md"
    monkeypatch.setenv("GITHUB_STEP_SUMMARY", str(summary))
    monkeypatch.setattr(sys, "argv", ["check_regression",
                                      "--out", str(out),
                                      "--baselines", str(base)])
    assert check_regression.main() == 0
    got = capsys.readouterr().out
    warn = [l for l in got.splitlines() if l.startswith("::warning")]
    assert len(warn) == 1 and "my/loose" in warn[0]
    assert "my/gated" not in warn[0]
    table = summary.read_text()
    assert "mybench/gated_speed" in table and "ok" in table
