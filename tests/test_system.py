"""End-to-end behaviour tests for the paper's system.

The headline test trains a small model on the synthetic needle-retrieval
task until it solves it, then verifies QUOKA's chunked prefill preserves the
retrieval — the in-repo analogue of the paper's NIAH experiment (§4.1).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.synthetic import (needle_accuracy, needle_batch,
                                  needle_batches)
from repro.models.model import build_model
from repro.training import loop as train_loop
from repro.training import optimizer as opt

KEY = jax.random.PRNGKey(0)

# minutes-long trained-model accuracy proxy (paper §4.1) — excluded from
# the fast CI tier
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def retrieval_model():
    """Train a 2-layer model on needle retrieval until accuracy is high."""
    cfg = get_config("granite-3-2b").smoke(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab=256)
    cfg = dataclasses.replace(
        cfg, quoka=dataclasses.replace(cfg.quoka, chunk_size=32, budget=48,
                                       n_queries=8, keep_first=4))
    model = build_model(cfg)
    gen = needle_batches(KEY, cfg.vocab, 16, 97, n_keys=16)
    state, hist = train_loop.train(
        model, gen, steps=250, log_every=100,
        ocfg=opt.OptimizerConfig(lr=3e-3, warmup_steps=20, total_steps=250))
    return model, state.params, cfg


def test_trained_model_solves_retrieval_dense(retrieval_model):
    model, params, cfg = retrieval_model
    rng = np.random.default_rng(7)
    batch = needle_batch(rng, cfg.vocab, 16, 97, n_keys=16)
    acc = needle_accuracy(model, params, batch, "full")
    assert acc >= 0.7, acc


def test_quoka_preserves_retrieval(retrieval_model):
    """QUOKA chunked prefill keeps the trained model's retrieval ability
    (paper §4.1) on longer prompts than it was trained on."""
    model, params, cfg = retrieval_model
    rng = np.random.default_rng(11)
    batch = needle_batch(rng, cfg.vocab, 16, 161, n_keys=16)
    acc_full = needle_accuracy(model, params, batch, "full")
    acc_quoka = needle_accuracy(model, params, batch, "quoka")
    assert acc_quoka >= acc_full - 0.25, (acc_quoka, acc_full)


def test_generation_roundtrip(retrieval_model):
    from repro.serving.engine import Engine
    model, params, cfg = retrieval_model
    eng = Engine(model, params, method="quoka")
    rng = np.random.default_rng(3)
    batch = needle_batch(rng, cfg.vocab, 4, 97, n_keys=16)
    res = eng.generate(eng.pad_prompt(np.asarray(batch["tokens"][:, :-1])), 4)
    assert res.tokens.shape == (4, 4)
    assert res.ttft_s > 0 and np.isfinite(res.decode_tps)
