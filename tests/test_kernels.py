"""Pallas kernels vs pure-jnp oracles (interpret=True), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_bhtd
from repro.kernels.ops import flash_attention, quoka_score
from repro.kernels.quoka_score import quoka_score_bhtd

KEY = jax.random.PRNGKey(0)

FLASH_CASES = [
    # (b, h, h_kv, tq, tk, d, causal, boundary)
    (1, 4, 2, 128, 256, 64, True, 0),
    (2, 8, 8, 64, 192, 32, True, 64),
    (1, 2, 1, 37, 119, 80, True, 16),       # ragged
    (1, 4, 4, 16, 300, 64, False, 0),       # cross attention
    (1, 1, 1, 8, 8, 8, True, 0),            # tiny
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_kernel_matches_ref(case, dtype):
    b, h, hkv, tq, tk, d, causal, boundary = case
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (b, h, tq, d), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 2), (b, hkv, tk, d), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 3), (b, hkv, tk, d), dtype)
    valid = jax.random.bernoulli(jax.random.fold_in(KEY, 4), 0.9, (b, tk))
    out = flash_attention_bhtd(q, k, v, valid, causal=causal,
                               boundary=boundary, block_q=32, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal,
                                   boundary=boundary, k_valid=valid)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_all_keys_invalid_rows_zero():
    b, h, tq, tk, d = 1, 2, 16, 64, 32
    q = jax.random.normal(KEY, (b, h, tq, d))
    k = jax.random.normal(KEY, (b, h, tk, d))
    v = jax.random.normal(KEY, (b, h, tk, d))
    valid = jnp.zeros((b, tk), bool)
    out = flash_attention_bhtd(q, k, v, valid, causal=False, block_q=16,
                               block_k=32)
    assert float(jnp.abs(out).max()) == 0.0


SCORE_CASES = [
    (2, 4, 16, 512, 64),
    (1, 1, 16, 300, 576),     # MLA-latent-like single-kv-head
    (2, 2, 5, 100, 80),
    (1, 8, 1, 128, 128),      # single query (decode)
]


@pytest.mark.parametrize("case", SCORE_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quoka_score_kernel_matches_ref(case, dtype):
    b, nkv, nq, t, d = case
    qb = jax.random.normal(jax.random.fold_in(KEY, 5), (b, nkv, nq, d), dtype)
    qb = qb / jnp.linalg.norm(qb.astype(jnp.float32), axis=-1,
                              keepdims=True).astype(dtype)
    kk = jax.random.normal(jax.random.fold_in(KEY, 6), (b, nkv, t, d), dtype)
    valid = jax.random.bernoulli(jax.random.fold_in(KEY, 7), 0.8, (b, t))
    out = quoka_score_bhtd(qb, kk, valid, block_t=128)
    want = ref.quoka_score_ref(qb, kk, valid)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=tol, rtol=tol)


def test_ops_wrappers_layouts():
    """ops.py converts BTHD <-> BHTD correctly on both backends."""
    b, t, h, hkv, d = 1, 64, 4, 2, 32
    q = jax.random.normal(KEY, (b, t, h, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, t, hkv, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, t, hkv, d))
    o_xla = flash_attention(q, k, v, backend="xla")
    o_pl = flash_attention(q, k, v, backend="pallas_interpret")
    assert o_xla.shape == (b, t, h, d)
    np.testing.assert_allclose(np.asarray(o_xla), np.asarray(o_pl),
                               atol=2e-5, rtol=1e-4)

    qb = jax.random.normal(KEY, (b, 8, hkv, d))
    qb = qb / jnp.linalg.norm(qb, axis=-1, keepdims=True)
    valid = jnp.ones((b, t), bool)
    s_xla = quoka_score(qb, k, valid, backend="xla")
    s_pl = quoka_score(qb, k, valid, backend="pallas_interpret")
    assert s_xla.shape == (b, hkv, t)
    np.testing.assert_allclose(np.asarray(s_xla), np.asarray(s_pl),
                               atol=1e-5, rtol=1e-5)
