"""Pallas kernels vs pure-jnp oracles (interpret=True), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_bhtd
from repro.kernels.ops import flash_attention, quoka_score
from repro.kernels.quoka_score import quoka_score_bhtd

KEY = jax.random.PRNGKey(0)

FLASH_CASES = [
    # (b, h, h_kv, tq, tk, d, causal, boundary)
    (1, 4, 2, 128, 256, 64, True, 0),
    (2, 8, 8, 64, 192, 32, True, 64),
    (1, 2, 1, 37, 119, 80, True, 16),       # ragged
    (1, 4, 4, 16, 300, 64, False, 0),       # cross attention
    (1, 1, 1, 8, 8, 8, True, 0),            # tiny
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_kernel_matches_ref(case, dtype):
    b, h, hkv, tq, tk, d, causal, boundary = case
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (b, h, tq, d), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 2), (b, hkv, tk, d), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 3), (b, hkv, tk, d), dtype)
    valid = jax.random.bernoulli(jax.random.fold_in(KEY, 4), 0.9, (b, tk))
    out = flash_attention_bhtd(q, k, v, valid, causal=causal,
                               boundary=boundary, block_q=32, block_k=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal,
                                   boundary=boundary, k_valid=valid)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_all_keys_invalid_rows_zero():
    b, h, tq, tk, d = 1, 2, 16, 64, 32
    q = jax.random.normal(KEY, (b, h, tq, d))
    k = jax.random.normal(KEY, (b, h, tk, d))
    v = jax.random.normal(KEY, (b, h, tk, d))
    valid = jnp.zeros((b, tk), bool)
    out = flash_attention_bhtd(q, k, v, valid, causal=False, block_q=16,
                               block_k=32)
    assert float(jnp.abs(out).max()) == 0.0


SCORE_CASES = [
    (2, 4, 16, 512, 64),
    (1, 1, 16, 300, 576),     # MLA-latent-like single-kv-head
    (2, 2, 5, 100, 80),
    (1, 8, 1, 128, 128),      # single query (decode)
]


@pytest.mark.parametrize("case", SCORE_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quoka_score_kernel_matches_ref(case, dtype):
    b, nkv, nq, t, d = case
    qb = jax.random.normal(jax.random.fold_in(KEY, 5), (b, nkv, nq, d), dtype)
    qb = qb / jnp.linalg.norm(qb.astype(jnp.float32), axis=-1,
                              keepdims=True).astype(dtype)
    kk = jax.random.normal(jax.random.fold_in(KEY, 6), (b, nkv, t, d), dtype)
    valid = jax.random.bernoulli(jax.random.fold_in(KEY, 7), 0.8, (b, t))
    out = quoka_score_bhtd(qb, kk, valid, block_t=128)
    want = ref.quoka_score_ref(qb, kk, valid)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=tol, rtol=tol)


def test_ops_wrappers_layouts():
    """ops.py converts BTHD <-> BHTD correctly on both backends."""
    b, t, h, hkv, d = 1, 64, 4, 2, 32
    q = jax.random.normal(KEY, (b, t, h, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, t, hkv, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, t, hkv, d))
    o_xla = flash_attention(q, k, v, backend="xla")
    o_pl = flash_attention(q, k, v, backend="pallas_interpret")
    assert o_xla.shape == (b, t, h, d)
    np.testing.assert_allclose(np.asarray(o_xla), np.asarray(o_pl),
                               atol=2e-5, rtol=1e-4)

    qb = jax.random.normal(KEY, (b, 8, hkv, d))
    qb = qb / jnp.linalg.norm(qb, axis=-1, keepdims=True)
    valid = jnp.ones((b, t), bool)
    s_xla = quoka_score(qb, k, valid, backend="xla")
    s_pl = quoka_score(qb, k, valid, backend="pallas_interpret")
    assert s_xla.shape == (b, hkv, t)
    np.testing.assert_allclose(np.asarray(s_xla), np.asarray(s_pl),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# satellite: fully-masked query rows must yield zeros, never NaN/Inf
# ---------------------------------------------------------------------------

def test_flash_midstream_all_invalid_block_per_kv_head():
    """One key block fully invalid for one KV head, mid-stream, under the
    causal [boundary | chunk] mask: every output must stay finite and match
    the oracle (the online-softmax l==0 guard)."""
    b, h, hkv, tq, tk, d = 1, 4, 2, 32, 128, 16
    q = jax.random.normal(jax.random.fold_in(KEY, 11), (b, h, tq, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 12), (b, hkv, tk, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 13), (b, hkv, tk, d))
    valid = np.ones((b, hkv, tk), bool)
    valid[:, 0, 32:64] = False          # kv-head 0: key block 1 fully masked
    valid = jnp.asarray(valid)
    out = flash_attention_bhtd(q, k, v, valid, causal=True, boundary=64,
                               block_q=16, block_k=32)
    want = ref.flash_attention_ref(q, k, v, causal=True, boundary=64,
                                   k_valid=valid)
    assert bool(jnp.isfinite(out).all())
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_one_head_all_invalid_rows_zero():
    """All keys invalid on ONE KV head: that head's outputs are exactly
    zero, the other heads are untouched."""
    b, h, hkv, tq, tk, d = 1, 4, 2, 16, 64, 16
    q = jax.random.normal(jax.random.fold_in(KEY, 14), (b, h, tq, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 15), (b, hkv, tk, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 16), (b, hkv, tk, d))
    valid = np.ones((b, hkv, tk), bool)
    valid[:, 0, :] = False
    valid = jnp.asarray(valid)
    out = flash_attention_bhtd(q, k, v, valid, causal=False,
                               block_q=16, block_k=32)
    want = ref.flash_attention_ref(q, k, v, causal=False, k_valid=valid)
    assert float(jnp.abs(out[:, 0::hkv][:, :1]).max()) >= 0  # shape sanity
    assert float(jnp.abs(out[:, : h // hkv]).max()) == 0.0   # head group 0
    assert bool(jnp.isfinite(out).all())
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_causal_first_row_masked_key_yields_zeros():
    """Causal, boundary=0, key 0 invalid: query row 0 attends NOTHING —
    the finalize divide must produce zeros, not NaN."""
    b, h, tq, tk, d = 1, 2, 8, 8, 16
    q = jax.random.normal(jax.random.fold_in(KEY, 17), (b, h, tq, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 18), (b, h, tk, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 19), (b, h, tk, d))
    valid = jnp.asarray(np.array([[False] + [True] * (tk - 1)]))
    out = flash_attention_bhtd(q, k, v, valid, causal=True,
                               block_q=8, block_k=8)
    assert bool(jnp.isfinite(out).all())
    assert float(jnp.abs(out[:, :, 0]).max()) == 0.0
    want = ref.flash_attention_ref(q, k, v, causal=True, k_valid=valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# tentpole: gather-free fused selected attention ≡ staged materialize+attend
# ---------------------------------------------------------------------------

from repro.configs.base import QuokaConfig              # noqa: E402
from repro.core import plan as plan_mod                 # noqa: E402
from repro.kernels import ops as kops                   # noqa: E402


def _staged_selected(q, k, v, key_pos, idx, start, g):
    """The staged pipeline the fused kernel replaces: plan.materialize's
    gather + [selected | causal-chunk] ops.attention over the concat."""
    b, chunk = q.shape[0], q.shape[1]
    n_kv = k.shape[2]
    idx = jnp.asarray(idx, jnp.int32)
    if g == 1 and idx.ndim == 2:        # head-shared token plan
        idx = jnp.broadcast_to(idx[:, None, :], (b, n_kv, idx.shape[-1]))
    sel = plan_mod.materialize(plan_mod.SelectionPlan(idx=idx), k, v,
                               key_pos, jnp.int32(start),
                               QuokaConfig(granularity=g))
    s = int(start)
    kc, vc = k[:, s:s + chunk], v[:, s:s + chunk]
    pc = key_pos[:, s:s + chunk]
    k_valid = jnp.concatenate(
        [sel.pos >= 0,
         jnp.broadcast_to((pc >= 0)[:, None, :], (b, n_kv, chunk))], axis=-1)
    return kops.attention(q, jnp.concatenate([sel.k, kc], axis=1),
                          jnp.concatenate([sel.v, vc], axis=1), k_valid,
                          causal=True, boundary=sel.pos.shape[-1],
                          backend="xla")


def _fused_case(case, rng_base=21):
    """(q, k, v, key_pos, idx, start, g) for one geometry tuple."""
    g, b, h, n_kv, T, chunk, start, nsel, seed = case
    q = jax.random.normal(jax.random.fold_in(KEY, rng_base + seed),
                          (b, chunk, h, 16))
    k = jax.random.normal(jax.random.fold_in(KEY, rng_base + seed + 1),
                          (b, T, n_kv, 16))
    v = jax.random.normal(jax.random.fold_in(KEY, rng_base + seed + 2),
                          (b, T, n_kv, 16))
    key_pos = jnp.arange(T, dtype=jnp.int32)[None].repeat(b, 0)
    rng = np.random.default_rng(seed)
    if g > 1:
        hi = -(-max(start, 1) // g)     # blocks touching prior context,
        idx = np.full((b, nsel), -1, np.int32)      # straddlers included
        for bi in range(b):
            n = min(nsel - 1, hi)
            idx[bi, :n] = rng.choice(hi, size=n, replace=False)
    else:
        idx = np.full((b, n_kv, nsel), -1, np.int32)
        for bi in range(b):
            for hh in range(n_kv):
                n = min(nsel - 1, max(start, 1))
                idx[bi, hh, :n] = rng.choice(
                    max(start + 2, 1), size=n, replace=False)  # some >= start
    return q, k, v, key_pos, jnp.asarray(idx), start, g


FUSED_CASES = [
    # (g, b, h, n_kv, T, chunk, start, n_sel_slots, seed)
    (16, 1, 4, 2, 256, 32, 48, 4, 0),      # block plan, aligned start
    (16, 2, 4, 2, 256, 32, 52, 4, 1),      # ragged start straddles a block
    (16, 1, 4, 4, 128, 16, 0, 3, 2),       # first chunk: nothing selectable
    (16, 1, 2, 1, 256, 1, 37, 5, 3),       # decode: t=1, misaligned start
    (1, 1, 4, 2, 128, 16, 80, 24, 4),      # token plan, per-KV-head idx
    (1, 1, 2, 2, 96, 32, 33, 17, 5),       # ragged chunk/boundary, g=1
]


@pytest.mark.parametrize("case", FUSED_CASES)
@pytest.mark.parametrize("backend", ["xla", "pallas_interpret"])
def test_selected_attention_matches_staged(case, backend):
    q, k, v, key_pos, idx, start, g = _fused_case(case)
    want = _staged_selected(q, k, v, key_pos, idx, start, g)
    out = kops.selected_attention(q, k, v, key_pos, idx, jnp.int32(start),
                                  granularity=g, backend=backend)
    assert out.shape == q.shape
    assert bool(jnp.isfinite(out).all())
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_selected_attention_shared_token_plan_2d_idx():
    """g == 1 with a head-shared (b, B) plan broadcasts across KV heads."""
    q, k, v, key_pos, idx3, start, g = _fused_case((1, 1, 4, 2, 128, 16,
                                                    64, 12, 6))
    idx2 = idx3[:, 0]
    want = _staged_selected(q, k, v, key_pos, idx2, start, 1)
    for backend in ("xla", "pallas_interpret"):
        out = kops.selected_attention(q, k, v, key_pos, idx2,
                                      jnp.int32(start), granularity=1,
                                      backend=backend)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)


def test_selected_attention_invalid_cache_slots():
    """key_pos == -1 (never-written cache rows) are masked inside the
    kernel even when the plan selects their block."""
    g, b, h, n_kv, T, chunk = 16, 1, 4, 2, 128, 16
    start = 48
    q = jax.random.normal(jax.random.fold_in(KEY, 31), (b, chunk, h, 16))
    k = jax.random.normal(jax.random.fold_in(KEY, 32), (b, T, n_kv, 16))
    v = jax.random.normal(jax.random.fold_in(KEY, 33), (b, T, n_kv, 16))
    key_pos = np.arange(T, dtype=np.int32)[None].repeat(b, 0)
    key_pos[:, 16:32] = -1              # block 1 was never written
    key_pos = jnp.asarray(key_pos)
    idx = jnp.asarray([[0, 1, 2, -1]], jnp.int32)
    want = _staged_selected(q, k, v, key_pos, idx, start, g)
    for backend in ("xla", "pallas_interpret"):
        out = kops.selected_attention(q, k, v, key_pos, idx,
                                      jnp.int32(start), granularity=g,
                                      backend=backend)
        assert bool(jnp.isfinite(out).all())
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)


def test_selected_attention_paged_block_table():
    """The paged path attends THROUGH the block table: permuted physical
    blocks, stale junk in unmapped blocks and a -1 table hole must all
    match the staged path on the equivalent linear view."""
    g, b, h, n_kv, d, bs = 16, 2, 4, 2, 16, 16
    nb_logical, chunk, start = 8, 16, 96
    T = nb_logical * bs
    N = nb_logical * b + 3              # spare physical blocks
    rng = np.random.default_rng(7)
    q = jax.random.normal(jax.random.fold_in(KEY, 41), (b, chunk, h, d))
    k_lin = jax.random.normal(jax.random.fold_in(KEY, 42), (b, T, n_kv, d))
    v_lin = jax.random.normal(jax.random.fold_in(KEY, 43), (b, T, n_kv, d))
    pos_lin = np.arange(T, dtype=np.int32)[None].repeat(b, 0)
    pos_lin[:, start + chunk:] = -1     # beyond the written prefix
    # scatter the linear views into a permuted pool; poison the spares
    perm = rng.permutation(N)
    k_pool = np.array(
        jax.random.normal(jax.random.fold_in(KEY, 44), (N, bs, n_kv, d)))
    v_pool = np.array(
        jax.random.normal(jax.random.fold_in(KEY, 45), (N, bs, n_kv, d)))
    pos_pool = rng.integers(0, T, (N, bs)).astype(np.int32)  # stale pos >= 0
    table = np.full((b, nb_logical), -1, np.int32)
    for bi in range(b):
        for lb in range(nb_logical):
            phys = int(perm[bi * nb_logical + lb])
            table[bi, lb] = phys
            k_pool[phys] = np.asarray(k_lin[bi, lb * bs:(lb + 1) * bs])
            v_pool[phys] = np.asarray(v_lin[bi, lb * bs:(lb + 1) * bs])
            pos_pool[phys] = pos_lin[bi, lb * bs:(lb + 1) * bs]
    table[1, -1] = -1                   # one unmapped logical block
    pos_lin[1, (nb_logical - 1) * bs:] = -1
    pos_lin = jnp.asarray(pos_lin)
    idx = jnp.asarray([[0, 2, 4, -1], [1, 3, 7, -1]], jnp.int32)
    want = _staged_selected(q, k_lin, v_lin, pos_lin, idx, start, g)
    for backend in ("xla", "pallas_interpret"):
        out = kops.selected_attention(
            q, jnp.asarray(k_pool), jnp.asarray(v_pool),
            jnp.asarray(pos_pool), idx, jnp.int32(start),
            granularity=g, backend=backend, table=jnp.asarray(table),
            block_size=bs)
        assert bool(jnp.isfinite(out).all())
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)


@pytest.mark.compiled
def test_compiled_kernels_match_oracle():
    """Compiled (non-interpret) Pallas kernels vs the XLA oracles.  Skips
    VISIBLY on hosts without a Pallas-compilable accelerator — the
    hardware-gated CI job runs it on real TPUs."""
    if jax.default_backend() == "cpu":
        pytest.skip("compiled Pallas kernels need a TPU/GPU backend; "
                    "CPU CI covers the interpret-mode parity suite")
    q, k, v, key_pos, idx, start, g = _fused_case(FUSED_CASES[0])
    want = _staged_selected(q, k, v, key_pos, idx, start, g)
    out = kops.selected_attention(q, k, v, key_pos, idx, jnp.int32(start),
                                  granularity=g, backend="pallas")
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-2, rtol=2e-2)
    o_flash = flash_attention(q, k, v, backend="pallas")
    w_flash = flash_attention(q, k, v, backend="xla")
    np.testing.assert_allclose(np.asarray(o_flash), np.asarray(w_flash),
                               atol=2e-2, rtol=2e-2)
