"""Serve-path telemetry (repro/obs): registry semantics, exporter formats,
and the metrics-on/off parity gate — attaching a registry to the engine must
not change a single emitted token (full + quoka, prefix-cache hit path
included), and a disabled registry must record nothing.  Also the
compile-time-exclusion regression test for ``Engine.generate``: the first
timed call must run AFTER a warmup execution of the jitted prefill/decode,
so ``ttft_s`` never includes trace+compile time."""
import json
import os
import re
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import build_model
from repro.obs import (NULL, Histogram, Registry, chrome_trace, export_all,
                       jsonl_lines, prometheus_text)
from repro.serving.engine import Engine
from repro.serving.request import make_requests

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_config("granite-3-2b").smoke()
    model = build_model(cfg)
    return cfg, model, model.init(KEY)


# ---------------------------------------------------------------------------
# registry unit
# ---------------------------------------------------------------------------

def test_registry_instruments_and_quantile_sanity():
    reg = Registry()
    reg.count("a/n", 2)
    reg.count("a/n")
    assert reg.counters["a/n"].value == 3.0
    reg.set("g", 4.5)
    assert reg.gauges["g"].value == 4.5
    h = reg.histogram("h")
    for v in range(100):
        h.observe(float(v))
    s = h.summary()
    assert s["count"] == 100 and s["min"] == 0.0 and s["max"] == 99.0
    assert s["min"] <= s["p50"] <= s["p90"] <= s["p99"] <= s["max"]
    assert abs(s["mean"] - 49.5) < 1e-9
    # same name -> same instrument (create-on-demand, no duplicates)
    assert reg.histogram("h") is h


def test_histogram_reservoir_bounded_and_deterministic():
    h1 = Histogram(reservoir=64, seed=3)
    h2 = Histogram(reservoir=64, seed=3)
    for v in range(1000):
        h1.observe(float(v))
        h2.observe(float(v))
    assert h1.count == 1000 and len(h1._res) == 64
    assert h1._res == h2._res                   # seeded: reproducible
    assert h1.min == 0.0 and h1.max == 999.0
    assert 0.0 <= h1.quantile(0.5) <= 999.0


def test_disabled_registry_records_nothing():
    reg = Registry(enabled=False)
    reg.count("x")
    reg.set("y", 1.0)
    reg.observe("z", 2.0)
    with reg.span("s"):
        pass
    reg.event("e", k=1)
    assert not reg.counters and not reg.gauges and not reg.histograms
    assert not reg.events and not reg.trace_events
    # null instruments are shared singletons, not per-call allocations
    assert NULL.counter("a") is NULL.counter("b")
    assert NULL.span("s") is NULL.span("t")


def test_span_times_into_histogram_and_trace():
    reg = Registry()
    with reg.span("step", rows=3):
        time.sleep(0.01)
    h = reg.histograms["step"]
    assert h.count == 1 and h.sum >= 0.009
    (ev,) = reg.trace_events
    assert ev["name"] == "step" and ev["ph"] == "X"
    assert ev["dur"] >= 0.009 * 1e6
    assert ev["args"] == {"rows": 3}


def test_scope_prefixes_and_view_round_trips():
    reg = Registry()
    sc = reg.scope("serve/prefix")
    sc.set("hits", 2)
    sc.count("reqs", 4)
    assert reg.gauges["serve/prefix/hits"].value == 2.0
    assert reg.view("serve/prefix") == {"hits": 2.0, "reqs": 4.0}
    assert sc.view() == reg.view("serve/prefix")


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def _populated_registry():
    reg = Registry()
    reg.count("sched/submitted", 3)
    reg.set("select/layer00/kv_fraction", 0.25)
    for v in (0.01, 0.02, 0.03):
        reg.observe("engine/decode_step", v)
    with reg.span("engine/prefill_step"):
        pass
    reg.event("serve_done", generated=12)
    return reg


def test_jsonl_export_parses():
    recs = [json.loads(line)
            for line in jsonl_lines(_populated_registry()).splitlines()
            if line]
    assert recs[0]["event"] == "serve_done" and recs[0]["generated"] == 12
    snap = recs[-1]
    assert snap["event"] == "snapshot"
    assert snap["counters"]["sched/submitted"] == 3.0
    assert snap["gauges"]["select/layer00/kv_fraction"] == 0.25
    assert snap["histograms"]["engine/decode_step"]["count"] == 3


def test_prometheus_export_format():
    txt = prometheus_text(_populated_registry())
    assert "select_layer00_kv_fraction 0.25" in txt
    assert 'engine_decode_step{quantile="0.5"}' in txt
    assert "engine_decode_step_count 3" in txt
    # exposition format 0.0.4: every sample line is `name[{labels}] value`
    sample = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*"
                        r"(\{[a-zA-Z0-9_]+=\"[^\"]*\"\})? \S+$")
    for line in txt.splitlines():
        if line and not line.startswith("#"):
            assert sample.match(line), line


def test_chrome_trace_structure():
    trace = chrome_trace(_populated_registry())
    evs = trace["traceEvents"]
    assert evs[0]["ph"] == "M"                 # process_name metadata
    xs = [e for e in evs if e["ph"] == "X"]
    assert xs and all({"name", "ts", "dur", "pid", "tid"} <= set(e)
                      for e in xs)
    json.dumps(trace)                          # Perfetto-loadable JSON


def test_export_all_writes_files(tmp_path):
    paths = export_all(_populated_registry(), str(tmp_path), prefix="t")
    assert set(paths) == {"jsonl", "prometheus", "trace"}
    for p in paths.values():
        assert os.path.getsize(p) > 0


# ---------------------------------------------------------------------------
# serve parity + invariants
# ---------------------------------------------------------------------------

def _serve_twice(engine, prompts, max_new):
    """Cold pass + warm (prefix-hit) pass over one pool."""
    state = engine.make_serve_state(make_requests(prompts, max_new),
                                    max_decode_batch=4)
    cold = engine.serve(make_requests(prompts, max_new), state=state)
    hot = engine.serve(make_requests(prompts, max_new), state=state)
    return cold, hot


@pytest.mark.parametrize("method", ["full", "quoka"])
def test_serve_metrics_on_off_token_identical(smoke_model, method):
    cfg, model, p = smoke_model
    rng = np.random.default_rng(7)
    sys_tok = rng.integers(3, cfg.vocab, (48,)).astype(np.int32)
    prompts = [np.concatenate(
        [sys_tok, rng.integers(3, cfg.vocab, (16,)).astype(np.int32)])
        for _ in range(3)]
    off = Engine(model, p, method=method)
    cold_off, hot_off = _serve_twice(off, prompts, 5)
    reg = Registry()
    on = Engine(model, p, method=method, registry=reg)
    cold_on, hot_on = _serve_twice(on, prompts, 5)
    assert all(v > 0 for v in hot_on.cached_len.values())   # hit path ran
    for rid in cold_off.tokens:
        np.testing.assert_array_equal(cold_off.tokens[rid],
                                      cold_on.tokens[rid])
        np.testing.assert_array_equal(hot_off.tokens[rid],
                                      hot_on.tokens[rid])
    # stats stay the backward-compat dict shape on both paths
    assert off.stats == on.stats
    assert hot_on.prefix["cache_hits"] == 3


def test_registry_invariants_after_serve(smoke_model):
    cfg, model, p = smoke_model
    reg = Registry()
    eng = Engine(model, p, method="quoka", registry=reg)
    rng = np.random.default_rng(11)
    # long enough that selection engages (capacity > budget + chunk), so
    # the per-layer budget gauges are populated in BOTH phases
    prompts = [rng.integers(3, cfg.vocab, (96,)).astype(np.int32),
               rng.integers(3, cfg.vocab, (40,)).astype(np.int32)]
    eng.serve(make_requests(prompts, 4), max_decode_batch=2)
    c = {k: v.value for k, v in reg.counters.items()}
    # lifecycle conservation after drain: active == waiting == 0
    assert c["sched/submitted"] == c["sched/admitted"] == 2
    assert c["sched/finished"] == 2
    assert reg.gauges["sched/queue_depth"].value == 0.0
    # a plan was built at least once per selecting layer
    assert c["select/plan_refresh"] > 0
    # selected-KV fraction <= budget ratio, per layer
    layer_kv = [k for k in reg.gauges
                if k.startswith("select/layer") and k.endswith("kv_fraction")]
    assert layer_kv
    for k in layer_kv:
        bud = reg.gauges[k.replace("kv_fraction", "budget_fraction")]
        assert reg.gauges[k].value <= bud.value + 1e-6
    kv = reg.histograms["select/kv_fraction"]
    assert kv.count > 0 and 0.0 < kv.min and kv.max <= 1.0 + 1e-6
    # step spans recorded with sane quantiles
    for nm in ("engine/prefill_step", "engine/decode_step"):
        s = reg.histograms[nm].summary()
        assert 0.0 < s["min"] <= s["p50"] <= s["p99"] <= s["max"]
    assert 0.0 < reg.gauges["pool/occupancy"].value <= 1.0
    # per-request latency distributions
    assert reg.histograms["serve/ttft_s"].count == 2
    assert reg.counters["serve/tokens_generated"].value == 8.0


def test_metrics_overhead_bounded(smoke_model):
    """Telemetry must not dominate serve cost.  Generous bound: compile is
    excluded (both engines serve once to warm), and the runner is shared CI
    hardware, so assert within a loose factor + absolute slack rather than
    a tight ratio."""
    cfg, model, p = smoke_model
    rng = np.random.default_rng(13)
    prompts = [rng.integers(3, cfg.vocab, (48,)).astype(np.int32)
               for _ in range(3)]

    def timed(engine):
        engine.serve(make_requests(prompts, 6), max_decode_batch=4)  # warm
        t0 = time.perf_counter()
        engine.serve(make_requests(prompts, 6), max_decode_batch=4)
        return time.perf_counter() - t0

    t_off = timed(Engine(model, p, method="quoka"))
    t_on = timed(Engine(model, p, method="quoka", registry=Registry()))
    assert t_on <= 5.0 * t_off + 1.0, (t_on, t_off)


# ---------------------------------------------------------------------------
# in-jit obs contract
# ---------------------------------------------------------------------------

def test_prefill_chunk_obs_pytree_contract(smoke_model):
    from repro.core import plan as plan_mod
    cfg, model, p = smoke_model
    t = cfg.quoka.chunk_size
    tok = (np.arange(t, dtype=np.int32) % cfg.vocab)[None]
    last0, _ = model.prefill_chunk(p, {"tokens": tok}, 0,
                                   model.init_cache(1, 128), "quoka")
    last1, _, obs = model.prefill_chunk(p, {"tokens": tok}, 0,
                                        model.init_cache(1, 128), "quoka",
                                        with_obs=True)
    np.testing.assert_array_equal(np.asarray(last0), np.asarray(last1))
    assert isinstance(obs, plan_mod.LayerObs)
    n_layers = obs.sel_tokens.shape
    assert obs.sel_tokens.ndim == 1 and n_layers[0] >= 1
    for leaf in obs:
        assert leaf.shape == n_layers and leaf.dtype == np.float32


# ---------------------------------------------------------------------------
# generate() compile-time exclusion (benchmark-timing bugfix)
# ---------------------------------------------------------------------------

def test_generate_first_call_excludes_compile(smoke_model):
    """The TTFT clock must start AFTER a warmup execution on identical
    avals: mechanism-based check — the first generate() runs the jitted
    prefill twice (warmup + timed), repeat calls on the same signature
    exactly once."""
    cfg, model, p = smoke_model
    eng = Engine(model, p, method="full")
    calls = []
    real = eng._prefill
    eng._prefill = lambda *a: (calls.append(1), real(*a))[1]
    toks = (np.arange(32, dtype=np.int32) % cfg.vocab)[None]
    batch = eng.pad_prompt(toks)
    r1 = eng.generate(batch, 3)
    assert len(calls) == 2, "first call must warm the jit cache off-clock"
    assert eng._warmed                           # signature recorded
    r2 = eng.generate(batch, 3)
    assert len(calls) == 3, "warmed signature must skip the warmup pass"
    np.testing.assert_array_equal(r1.tokens, r2.tokens)
    # a NEW signature (different max_new class / shape) warms again
    eng.generate(batch, 1)
    assert len(calls) == 5
