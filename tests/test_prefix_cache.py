"""Prefix caching in the paged KV pool: content-addressed block sharing,
refcount/LRU invariants, copy-on-write tails, eviction under pressure, the
freed-block stamping regression (a recycled block must never leak a donor's
KV), and the parity gate — cache-hit serve() must stay token-identical to
cold per-request generate() for both full and quoka.

Note on alignment: QUOKA (and every selection baseline) scores per B_CP
chunk, so serve()-vs-generate() parity only holds when both sides chunk the
prompt on the same grid — generate() left-pads to a chunk multiple, which
shifts the grid for ragged prompts once the budget truncates.  quoka parity
cases therefore use chunk-multiple prompt lengths (as test_scheduler does);
dense attention is chunking-invariant, so `full` cases go ragged on purpose
to exercise COW tails.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import build_model
from repro.serving import request as rq
from repro.serving.engine import Engine
from repro.serving.pool import PagedKVCache, blocks_for_request
from repro.serving.request import make_requests
from repro.serving.scheduler import Scheduler

KEY = jax.random.PRNGKey(0)
BS = 16


@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_config("granite-3-2b").smoke()
    model = build_model(cfg)
    return cfg, model, model.init(KEY)


def _pos_leaves(data):
    return [l for l in jax.tree.leaves(data)
            if hasattr(l, "ndim") and l.ndim >= 3
            and jnp.issubdtype(l.dtype, jnp.integer)]


def _poison(data, blocks, value=3):
    """Plant valid-looking positions in ``blocks`` (simulates a donor's
    leftover KV)."""
    def f(leaf):
        if leaf.ndim >= 3 and jnp.issubdtype(leaf.dtype, jnp.integer):
            for b in blocks:
                leaf = leaf.at[:, b].set(value)
        return leaf
    return jax.tree.map(f, data)


# ---------------------------------------------------------------------------
# stamping regression (pool-reuse bugfix)
# ---------------------------------------------------------------------------

def test_free_stamps_released_blocks(smoke_model):
    """A freed block's positions must read as -1 before it can be handed to
    a new request: stale pos values from a donor that sat at a different
    logical offset would pass the validity masks and leak the donor's KV
    into the new request's attention."""
    _, model, _ = smoke_model
    pool = PagedKVCache(model, num_blocks=4, block_size=BS)
    held = pool.alloc(0, 2)
    pool.data = _poison(pool.data, held)        # donor wrote real positions
    pool.free(0)
    reused = pool.alloc(1, 2)
    assert set(reused) == set(held)             # same physical blocks
    for leaf in _pos_leaves(pool.data):
        got = np.asarray(leaf[:, np.asarray(reused)])
        assert (got == -1).all(), "stale positions leaked through free()"


def test_evicted_cached_block_is_stamped(smoke_model):
    """Registered blocks keep their content on the LRU list — but once
    evicted into a fresh allocation they must be stamped too."""
    _, model, _ = smoke_model
    pool = PagedKVCache(model, num_blocks=2, block_size=BS)
    toks = np.arange(BS, dtype=np.int32) + 3
    pool.alloc(0, 1)
    pool.data = _poison(pool.data, pool.table(0))
    pool.register_prefix(0, toks)               # block is now content-addressed
    pool.free(0)
    assert pool.num_evictable == 1              # resident, still matchable
    fulls, _ = pool.match_prefix(toks)
    assert len(fulls) == 1
    blocks = pool.alloc(1, 2)                   # forces the eviction
    assert pool.evictions == 1
    fulls, tail = pool.match_prefix(toks)
    assert fulls == [] and tail is None         # unregistered on eviction
    for leaf in _pos_leaves(pool.data):
        assert (np.asarray(leaf[:, np.asarray(blocks)]) == -1).all()
    pool.check_invariants()


# ---------------------------------------------------------------------------
# host-side bookkeeping: matching, refcounts, LRU
# ---------------------------------------------------------------------------

def test_match_prefix_follows_hash_chain(smoke_model):
    """Block identity covers its whole prefix: two donors sharing block 0
    but diverging in block 1 must not cross-match."""
    _, model, _ = smoke_model
    pool = PagedKVCache(model, num_blocks=8, block_size=BS)
    base = np.arange(BS, dtype=np.int32) + 3
    a = np.concatenate([base, np.full(BS, 7, np.int32)])
    b = np.concatenate([base, np.full(BS, 9, np.int32)])
    pool.alloc(0, 2)
    pool.register_prefix(0, a)
    fulls, _ = pool.match_prefix(a)
    assert fulls == pool.table(0)
    fulls_b, _ = pool.match_prefix(b)
    assert fulls_b == pool.table(0)[:1]         # shared first block only
    assert pool.match_prefix(np.full(BS, 11, np.int32))[0] == []
    # a partial query matches nothing at full-block granularity
    assert pool.match_prefix(a[:BS - 1]) == ([], None)
    pool.free(0)
    pool.check_invariants()


def test_refcount_invariants_random_hold_free(smoke_model):
    """Randomized admit/complete/free cycles over a tiny pool with heavily
    overlapping prompts: refcounts, free list, LRU and the hash indices
    stay mutually consistent; sharing, COW and eviction all trigger."""
    _, model, _ = smoke_model
    pool = PagedKVCache(model, num_blocks=10, block_size=BS)
    sched = Scheduler(pool, chunk_size=BS, max_prefill_tokens=BS,
                      max_decode_batch=8, prefix_cache=True, prefix_align=1)
    rng = np.random.default_rng(0)
    fams = [rng.integers(3, 100, (3 * BS,)).astype(np.int32)
            for _ in range(2)]
    held = {}
    rid = 0
    for step in range(300):
        if held and (rng.random() < 0.5 or not pool.can_alloc(4)):
            victim = int(rng.choice(list(held)))
            pool.free(victim)
            del held[victim]
        else:
            fam = fams[int(rng.integers(len(fams)))]
            plen = int(rng.integers(BS, len(fam)))
            toks = fam[:plen].copy()
            r = rq.Request(rid=rid, tokens=toks, max_new=1)
            cached, shared, cow = sched._match(r)
            n = blocks_for_request(plen, 1, BS, BS, cached_len=cached)
            protect = shared + ([cow[0]] if cow else [])
            if pool.can_alloc(n - len(shared), exclude=protect):
                pool.alloc_prefix(rid, n, shared, cow)
                assert cached <= plen - 1
                pool.register_prefix(rid, toks)
                held[rid] = True
                rid += 1
        pool.check_invariants()
    assert pool.hit_tokens == 0                 # counters are scheduler-owned
    assert pool.cow_copies > 0                  # partial tails shared
    assert pool.evictions > 0                   # pressure reached the LRU
    for r_ in list(held):
        pool.free(r_)
    pool.check_invariants()
    assert pool.num_free + pool.num_evictable == 10


def test_shared_blocks_not_evictable_for_same_request(smoke_model):
    """A request's fresh-block allocation must never evict the prefix
    blocks it is about to share (pin-before-alloc ordering)."""
    _, model, _ = smoke_model
    pool = PagedKVCache(model, num_blocks=3, block_size=BS)
    toks = np.arange(2 * BS, dtype=np.int32) + 3
    pool.alloc(0, 2)
    pool.register_prefix(0, toks)
    pool.free(0)                                # both blocks on the LRU
    fulls, _ = pool.match_prefix(toks)
    table = pool.alloc_prefix(1, 3, shared=fulls)   # needs 1 fresh of 1 free
    assert table[:2] == fulls
    pool.check_invariants()
    # and when fresh demand exceeds free + non-shared LRU, refuse up front
    assert not pool.can_alloc(2, exclude=fulls)


# ---------------------------------------------------------------------------
# end-to-end: cache-hit serving parity, COW, eviction under pressure
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["full", "quoka"])
def test_cache_hit_serve_matches_cold_generate(smoke_model, method):
    """Pass 2 over a warm pool admits every request via a prefix hit and
    must reproduce per-request generate() token-for-token (chunk-multiple
    prompts: see module docstring)."""
    cfg, model, p = smoke_model
    eng = Engine(model, p, method=method)
    rng = np.random.default_rng(3)
    sys_tok = rng.integers(3, cfg.vocab, (48,)).astype(np.int32)
    prompts = [np.concatenate(
        [sys_tok, rng.integers(3, cfg.vocab, (16,)).astype(np.int32)])
        for _ in range(3)]
    refs = [eng.generate(eng.pad_prompt(pr[None]), 6).tokens[0]
            for pr in prompts]
    state = eng.make_serve_state(make_requests(prompts, 6), block_size=BS,
                                 max_decode_batch=4)
    cold = eng.serve(make_requests(prompts, 6), state=state)
    assert all(v == 0 for v in cold.cached_len.values())
    hot = eng.serve(make_requests(prompts, 6), state=state)
    assert all(v > 0 for v in hot.cached_len.values())
    if method != "full":                        # hits stay on the B_CP grid
        chunk = cfg.quoka.chunk_size
        assert all(v % chunk == 0 for v in hot.cached_len.values())
    assert eng.stats["cache_hits"] == 3
    assert eng.stats["hit_rate"] > 0.5
    for i, ref in enumerate(refs):
        np.testing.assert_array_equal(cold.tokens[i], ref)
        np.testing.assert_array_equal(hot.tokens[i], ref)
    state.pool.check_invariants()


def test_cow_shared_tail_multiturn(smoke_model):
    """Multi-turn shape: turn 2's prompt extends turn 1's ragged prompt, so
    the shared prefix ends inside a partially filled block — served via a
    copy-on-write clone of the donor's tail block (dense attention: hits at
    token granularity)."""
    cfg, model, p = smoke_model
    eng = Engine(model, p, method="full")
    rng = np.random.default_rng(5)
    base = rng.integers(3, cfg.vocab, (40,)).astype(np.int32)   # 2.5 blocks
    turn2 = np.concatenate(
        [base, rng.integers(3, cfg.vocab, (13,)).astype(np.int32)])
    ref1 = eng.generate(eng.pad_prompt(base[None]), 4).tokens[0]
    ref2 = eng.generate(eng.pad_prompt(turn2[None]), 4).tokens[0]
    state = eng.make_serve_state(make_requests([base, turn2], 4),
                                 block_size=BS, max_decode_batch=2)
    r1 = eng.serve(make_requests([base], 4), state=state)
    np.testing.assert_array_equal(r1.tokens[0], ref1)
    r2 = eng.serve([rq.Request(rid=9, tokens=turn2, max_new=4)], state=state)
    assert r2.cached_len[9] == 40               # 2 full blocks + 8-token COW
    assert eng.stats["cow_copies"] == 1
    np.testing.assert_array_equal(r2.tokens[9], ref2)
    # the donor's tail block itself must be unaffected by the sharer
    r1b = eng.serve(make_requests([base], 4), state=state)
    assert r1b.cached_len[0] == 39              # capped at prompt_len - 1
    np.testing.assert_array_equal(r1b.tokens[0], ref1)
    state.pool.check_invariants()


def test_lru_eviction_under_memory_pressure(smoke_model):
    """A pool too small to retain every trace's blocks evicts oldest-first;
    serving stays correct and invariant-clean throughout."""
    cfg, model, p = smoke_model
    eng = Engine(model, p, method="quoka")
    rng = np.random.default_rng(7)
    prompts = [rng.integers(3, cfg.vocab, (32,)).astype(np.int32)
               for _ in range(4)]
    refs = [eng.generate(eng.pad_prompt(pr[None]), 4).tokens[0]
            for pr in prompts]
    state = eng.make_serve_state(make_requests(prompts[:1], 4),
                                 block_size=BS, num_blocks=4,
                                 max_decode_batch=2)
    for i, pr in enumerate(prompts):            # distinct prompts: no hits,
        res = eng.serve(make_requests([pr], 4), state=state)   # all pressure
        np.testing.assert_array_equal(res.tokens[0], refs[i])
        state.pool.check_invariants()
    assert state.pool.evictions > 0
    # the newest registered prefix is still matchable, the oldest is gone
    fulls, _ = state.pool.match_prefix(prompts[-1])
    assert len(fulls) > 0
    assert state.pool.match_prefix(prompts[0]) == ([], None)


def test_hit_degrades_to_cold_admit_on_tight_pool(smoke_model):
    """A token-granularity hit can need MORE blocks than a cold admit
    (shifted chunk grid) while its shared/COW-source blocks are protected
    from eviction; on a pool sized exactly for the cold request, admission
    must degrade to a cold admit instead of stalling the FCFS head."""
    cfg, model, p = smoke_model
    eng = Engine(model, p, method="full")
    rng = np.random.default_rng(13)
    pr = rng.integers(3, cfg.vocab, (32,)).astype(np.int32)
    ref = eng.generate(eng.pad_prompt(pr[None]), 1).tokens[0]
    state = eng.make_serve_state(make_requests([pr], 1), block_size=BS,
                                 num_blocks=3, max_decode_batch=2)
    eng.serve(make_requests([pr], 1), state=state)
    res = eng.serve(make_requests([pr], 1), state=state)   # would stall
    assert res.cached_len[0] == 0                          # degraded
    np.testing.assert_array_equal(res.tokens[0], ref)
    state.pool.check_invariants()


def test_serve_state_rejects_conflicting_kwargs(smoke_model):
    cfg, model, p = smoke_model
    eng = Engine(model, p, method="quoka")
    rng = np.random.default_rng(15)
    pr = rng.integers(3, cfg.vocab, (16,)).astype(np.int32)
    state = eng.make_serve_state(make_requests([pr], 2), block_size=BS,
                                 max_decode_batch=2)
    with pytest.raises(ValueError, match="make_serve_state"):
        eng.serve(make_requests([pr], 2), state=state, prefix_cache=False)
    with pytest.raises(ValueError, match="make_serve_state"):
        eng.serve(make_requests([pr], 2), state=state, num_blocks=8)
    eng.serve(make_requests([pr], 2), state=state)         # clean call OK


def test_serve_state_geometry_guard(smoke_model):
    """Reusing a warm state with a trace that outgrows the compiled
    geometry must fail loudly, not truncate."""
    cfg, model, p = smoke_model
    eng = Engine(model, p, method="quoka")
    rng = np.random.default_rng(9)
    small = rng.integers(3, cfg.vocab, (16,)).astype(np.int32)
    big = rng.integers(3, cfg.vocab, (96,)).astype(np.int32)
    state = eng.make_serve_state(make_requests([small], 4), block_size=BS,
                                 max_decode_batch=2)
    eng.serve(make_requests([small], 4), state=state)
    with pytest.raises(ValueError, match="fresh state"):
        eng.serve(make_requests([big], 4), state=state)


def test_prefix_cache_off_never_hits(smoke_model):
    cfg, model, p = smoke_model
    eng = Engine(model, p, method="quoka")
    rng = np.random.default_rng(11)
    pr = rng.integers(3, cfg.vocab, (32,)).astype(np.int32)
    state = eng.make_serve_state(make_requests([pr], 4), block_size=BS,
                                 max_decode_batch=2, prefix_cache=False)
    eng.serve(make_requests([pr], 4), state=state)
    res = eng.serve(make_requests([pr], 4), state=state)
    assert res.cached_len[0] == 0
    assert eng.stats["cache_hits"] == 0
    assert state.pool.num_cached == 0
