"""End-to-end fused selected-attention route: chunked prefill and the
serving engine produce the SAME results with ``fused_select_attn`` on and
off, and the fused serving step lowers WITHOUT the plan_materialize gather
(the tentpole's whole point — analysis/hlo.py proves it on the real jitted
step, not a toy)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import hlo
from repro.configs.base import QuokaConfig, get_config
from repro.core.chunked_prefill import chunked_sparse_attention
from repro.models.model import Model
from repro.serving.engine import Engine
from repro.serving.request import make_requests

KEY = jax.random.PRNGKey(0)


def _qkv(t=256, h=4, n_kv=2, d=16):
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (1, t, h, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 2), (1, t, n_kv, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 3), (1, t, n_kv, d))
    return q, k, v


@pytest.mark.parametrize("backend,tol", [("xla", 0.0),
                                         ("pallas_interpret", 4e-7)])
def test_chunked_prefill_fused_matches_staged(backend, tol):
    """chunked_sparse_attention with fused_select_attn routes every chunk
    through ops.selected_attention; outputs must match the staged
    materialize+attend route (bit-identical on xla — same oracle math)."""
    q, k, v = _qkv()
    base = QuokaConfig(chunk_size=32, budget=64, n_queries=8,
                       granularity=16, backend=backend)
    outs = {}
    for fused in (False, True):
        cfg = dataclasses.replace(base, fused_select_attn=fused)
        outs[fused] = chunked_sparse_attention(q, k, v, cfg, method="quoka",
                                               backend=backend)
    a, b = np.asarray(outs[False]), np.asarray(outs[True])
    assert np.isfinite(a).all() and np.isfinite(b).all()
    if tol == 0.0:
        np.testing.assert_array_equal(a, b)
    else:
        np.testing.assert_allclose(a, b, atol=tol, rtol=tol)


def _engine(fused: bool):
    cfg = get_config("qwen3-4b").smoke()
    qcfg = dataclasses.replace(cfg.quoka, granularity=16, budget=32,
                               fused_select_attn=fused, method="quoka")
    cfg = dataclasses.replace(cfg, quoka=qcfg)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return Engine(model, params, method="quoka", backend="pallas_interpret")


def test_engine_serve_fused_token_parity():
    """Greedy serve emits IDENTICAL tokens with the fused kernel on and
    off — the strongest end-to-end equivalence the engine can give."""
    prompts = [list(range(1, 40)), list(range(7, 29))]
    toks = {}
    for fused in (False, True):
        eng = _engine(fused)
        assert eng.fused is fused
        res = eng.serve(make_requests(prompts, 5), block_size=16,
                        max_decode_batch=2)
        toks[fused] = {r: np.asarray(t) for r, t in res.tokens.items()}
    assert toks[False].keys() == toks[True].keys()
    for rid in toks[False]:
        np.testing.assert_array_equal(toks[False][rid], toks[True][rid])


def test_fused_serving_step_has_no_materialize_gather():
    """HLO-level acceptance: the STAGED prefill step lowers with gathers
    inside the plan_materialize scope (proving the scope survives into the
    HLO we inspect), the FUSED step lowers with none.  Prompts must exceed
    the budget (32 tokens) — shorter priors take the select-all shortcut
    and neither arm materializes a plan."""
    prompts = [list(range(1, 90)), list(range(7, 60))]
    counts = {}
    for fused in (False, True):
        eng = _engine(fused)
        reqs = make_requests(prompts, 3)
        st = eng.make_serve_state(reqs, block_size=16, max_decode_batch=2)
        cap = {}
        orig = st.fns[0]

        def wrapper(*args, _orig=orig, _cap=cap):
            _cap["args"] = args
            return _orig(*args)

        st2 = dataclasses.replace(st, fns=(wrapper, st.fns[1]))
        eng.serve(reqs, state=st2)
        text = orig.lower(*cap["args"]).compile().as_text()
        counts[fused] = hlo.gathers_in_scope(text, "plan_materialize")
    assert counts[False], "staged step lost the plan_materialize scope " \
                          "— the fused==[] assertion below would be vacuous"
    assert counts[True] == []
