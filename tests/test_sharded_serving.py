"""Tensor-parallel sharded serving: token parity + collective hygiene.

Everything runs in ONE subprocess under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the flag must be
set before jax initialises, and must never leak into this process — smoke
tests and benchmarks need one real device) on a ``(data=2, model=4)`` host
mesh, the regime the old ``core/quoka.py`` §Perf A7 note documented:
granite's smoke config has n_kv = 2 < |model| = 4, so the score tensor
under-shards and the T-local shard_map path must engage.

Checked:
  * ``generate`` and greedy ``serve`` on the mesh are token-identical to
    the unsharded engine for ``full`` AND ``quoka``, including a second
    serve pass admitted through prefix-cache hits over a warm pool.
  * the sharded scoring pass issues no full-cache all-gather: the compiled
    HLO of a jitted ``plan.select`` carries only the candidate-merge
    all-gather (a few hundred bytes), orders of magnitude below the K
    cache it used to reshard (analysis/hlo.py byte accounting).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import sys
    sys.path.insert(0, __SRC__)
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.analysis import hlo
    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.models.model import build_model
    from repro.serving.engine import Engine
    from repro.serving.request import make_requests
    from repro.sharding import ctx as shctx

    cfg = get_config("granite-3-2b").smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mesh = make_host_mesh(model=4, data=2)
    assert cfg.n_kv_heads % 4 != 0      # the documented under-sharding case
    rng = np.random.default_rng(3)
    prompts = [rng.integers(3, cfg.vocab, (n,)).astype(np.int32)
               for n in (16, 48, 29)]
    out = {}
    for method in ("full", "quoka"):
        ref = Engine(model, params, method=method)
        shd = Engine(model, params, method=method, mesh=mesh)
        toks = np.stack([prompts[1], prompts[1][::-1].copy()])
        rg = ref.generate(ref.pad_prompt(toks), 6)
        sg = shd.generate(shd.pad_prompt(toks), 6)
        out[method + "/generate"] = bool(np.array_equal(rg.tokens, sg.tokens))

        kw = dict(block_size=16, max_decode_batch=4, max_prefill_tokens=32)
        r1 = ref.serve(make_requests(prompts, 5), **kw)
        st = shd.make_serve_state(make_requests(prompts, 5), **kw)
        s1 = shd.serve(make_requests(prompts, 5), state=st)
        s2 = shd.serve(make_requests(prompts, 5), state=st)   # warm pool
        out[method + "/serve"] = all(
            np.array_equal(r1.tokens[i], s1.tokens[i])
            for i in range(len(prompts)))
        out[method + "/serve_prefix_hit"] = all(
            np.array_equal(s1.tokens[i], s2.tokens[i])
            for i in range(len(prompts)))
        out[method + "/cache_hits"] = int(shd.stats["cache_hits"])

    # ---- HLO: the sharded scoring pass must not reshard the K cache ----
    from repro.core import plan as plan_mod
    b, t, h, n_kv, d = 2, 64, cfg.n_heads, cfg.n_kv_heads, \\
        cfg.resolved_head_dim
    q = jax.random.normal(jax.random.PRNGKey(1), (b, 16, h, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(2), (b, t, n_kv, d),
                          jnp.float32)
    pos = jnp.arange(t, dtype=jnp.int32)[None].repeat(b, 0)
    fn = jax.jit(lambda q, k, v, p: plan_mod.select(
        "quoka", q, k, v, p, jnp.asarray(48), cfg.quoka))
    snap = shctx.get_policy()
    shctx.set_policy(mesh, ("data",))
    try:
        with mesh:
            comp = fn.lower(q, k, k, pos).compile()
    finally:
        shctx.restore_policy(snap)
    coll = hlo.collective_bytes(comp.as_text())
    k_bytes = b * t * n_kv * d * 4
    out["score_allgather_bytes"] = coll.get("all-gather", 0)
    out["k_cache_bytes"] = k_bytes
    print("RESULT", json.dumps(out))
""")


@pytest.fixture(scope="module")
def subproc_result():
    code = SUBPROC.replace("__SRC__", repr(os.path.abspath(SRC)))
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900)
    for line in res.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise AssertionError(f"subprocess failed:\n{res.stderr[-3000:]}")


@pytest.mark.slow
@pytest.mark.parametrize("method", ["full", "quoka"])
def test_sharded_token_parity(subproc_result, method):
    """Sharded generate/serve == unsharded, token for token, incl. a
    prefix-cache-hit admission over a warm pool."""
    assert subproc_result[f"{method}/generate"], subproc_result
    assert subproc_result[f"{method}/serve"], subproc_result
    assert subproc_result[f"{method}/serve_prefix_hit"], subproc_result
    assert subproc_result[f"{method}/cache_hits"] > 0, subproc_result


@pytest.mark.slow
def test_sharded_scoring_no_kv_cache_allgather(subproc_result):
    """Resolution of the old core/quoka.py §Perf A7 note: under tensor
    parallelism with an indivisible KV-head axis, the scoring pass moves
    only per-shard top-k candidates — never the K cache."""
    ag = subproc_result["score_allgather_bytes"]
    kb = subproc_result["k_cache_bytes"]
    assert ag > 0, "shard_map path did not engage (no candidate merge)"
    assert ag < kb / 4, (ag, kb)
