"""Chunked-prefill equivalence + selection-method behaviour (paper Alg. 2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import QuokaConfig
from repro.core.chunked_prefill import (chunked_sparse_attention,
                                        dense_causal_reference, key_recall,
                                        output_error)
from repro.core.selection import METHODS
from repro.data.synthetic import structured_qkv

KEY = jax.random.PRNGKey(7)
B, T, H, NKV, D = 2, 256, 4, 2, 32


def _qkv(key=KEY):
    q = jax.random.normal(key, (B, T, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, NKV, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, NKV, D))
    return q, k, v


def test_full_budget_is_exact():
    q, k, v = _qkv()
    cfg = QuokaConfig(chunk_size=64, budget=T, n_queries=16)
    out = chunked_sparse_attention(q, k, v, cfg, "quoka")
    ref = dense_causal_reference(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("method", [m for m in METHODS if m != "full"])
def test_methods_run_and_bounded_error(method):
    q, k, v = _qkv()
    cfg = QuokaConfig(chunk_size=64, budget=128, n_queries=16)
    err = output_error(q, k, v, cfg, method)
    assert np.isfinite(float(err))
    assert float(err) < 1.0


def test_error_decreases_with_budget():
    """Paper §4.5: accuracy degrades gradually/monotonically with sparsity."""
    q, k, v = structured_qkv(KEY, B, T, H, NKV, D)
    errs = []
    for budget in (32, 64, 128, 255):
        cfg = QuokaConfig(chunk_size=64, budget=budget, n_queries=16,
                          keep_first=4)
        errs.append(float(output_error(q, k, v, cfg, "quoka")))
    assert errs[-1] <= errs[0]
    assert errs[-1] < 0.1                       # near-exact at ~full budget


def test_quoka_beats_mean_aggregation_on_structured_geometry():
    """The paper's central mechanism: on Figure-2-like geometry (outlier
    queries pointing at needle keys, bulk queries on shared sinks), QUOKA's
    dissimilar-query subselection + max aggregation must beat uniform-sampled
    mean aggregation on output error and max-oracle key recall."""
    q, k, v = structured_qkv(jax.random.PRNGKey(3), 2, 512, 8, 2, 32)
    cfg = QuokaConfig(chunk_size=128, budget=64, n_queries=16, keep_first=4)
    r_quoka = float(key_recall(q, k, v, cfg, "quoka"))
    r_sample = float(key_recall(q, k, v, cfg, "sample_attention"))
    e_quoka = float(output_error(q, k, v, cfg, "quoka"))
    e_sample = float(output_error(q, k, v, cfg, "sample_attention"))
    assert r_quoka > r_sample, (r_quoka, r_sample)
    assert e_quoka < e_sample, (e_quoka, e_sample)


def test_causality_future_tokens_do_not_change_past():
    """Changing tokens after position p must not change outputs at <= p."""
    q, k, v = _qkv()
    cfg = QuokaConfig(chunk_size=64, budget=96, n_queries=8)
    out1 = chunked_sparse_attention(q, k, v, cfg, "quoka")
    q2 = q.at[:, -64:].set(jax.random.normal(jax.random.fold_in(KEY, 9),
                                             (B, 64, H, D)))
    k2 = k.at[:, -64:].set(jax.random.normal(jax.random.fold_in(KEY, 10),
                                             (B, 64, NKV, D)))
    out2 = chunked_sparse_attention(q2, k2, v, cfg, "quoka")
    np.testing.assert_allclose(np.asarray(out1[:, :-64]),
                               np.asarray(out2[:, :-64]),
                               atol=2e-5, rtol=1e-4)


def test_unroll_matches_scan():
    q, k, v = _qkv()
    cfg = QuokaConfig(chunk_size=64, budget=96, n_queries=8)
    a = chunked_sparse_attention(q, k, v, cfg, "quoka", unroll=False)
    b = chunked_sparse_attention(q, k, v, cfg, "quoka", unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=1e-5, rtol=1e-5)
