"""Serving correctness: cache mechanics, prefill<->train consistency,
prefill-then-decode continuity, engine generation."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model import build_model
from repro.serving.cache import kv_init, kv_write, kv_write_ring
from repro.serving.engine import Engine
from repro.serving.sampler import SamplerConfig, sample

KEY = jax.random.PRNGKey(0)


def test_kv_write_linear():
    c = kv_init(2, 16, 1, 4, jnp.float32)
    k = jnp.ones((2, 3, 1, 4))
    c = kv_write(c, k, k * 2, 5)
    assert bool((c.pos[:, 5:8] == jnp.arange(5, 8)).all())
    assert bool((c.pos[:, :5] == -1).all())
    np.testing.assert_allclose(np.asarray(c.v[:, 5:8]), 2.0)


def test_kv_write_ring_wraps():
    c = kv_init(1, 8, 1, 4, jnp.float32)
    k1 = jnp.arange(6, dtype=jnp.float32).reshape(1, 6, 1, 1).repeat(4, -1)
    c = kv_write_ring(c, k1, k1, 0)            # slots 0..5 = pos 0..5
    k2 = jnp.arange(6, 10, dtype=jnp.float32).reshape(1, 4, 1, 1).repeat(4, -1)
    c = kv_write_ring(c, k2, k2, 6)            # slots 6,7,0,1 = pos 6..9
    assert np.asarray(c.pos[0]).tolist() == [8, 9, 2, 3, 4, 5, 6, 7]
    np.testing.assert_allclose(float(c.k[0, 0, 0, 0]), 8.0)


def test_prefill_full_matches_train_logits():
    """Chunked prefill with method='full' must reproduce the training
    forward's last-position logits exactly (cache path correctness)."""
    cfg = get_config("granite-3-2b").smoke()
    model = build_model(cfg)
    p = model.init(KEY)
    batch = {"tokens": jax.random.randint(KEY, (2, 64), 0, cfg.vocab)}
    train_logits, _ = model.train_logits(p, batch)
    cache = model.init_cache(2, 64)
    pf_logits, _ = model.prefill(p, batch, cache, "full")
    np.testing.assert_allclose(np.asarray(pf_logits),
                               np.asarray(train_logits[:, -1]),
                               atol=2e-3, rtol=2e-3)


def test_prefill_then_decode_matches_train_logits():
    """Prefill T tokens then decode token T: logits must match the training
    forward over T+1 tokens at the last position (cache continuity)."""
    cfg = get_config("granite-3-2b").smoke()
    model = build_model(cfg)
    p = model.init(KEY)
    toks = jax.random.randint(KEY, (2, 65), 0, cfg.vocab)
    train_logits, _ = model.train_logits(p, {"tokens": toks})
    cache = model.init_cache(2, 80)
    _, cache = model.prefill(p, {"tokens": toks[:, :64]}, cache, "full")
    dec_logits, _ = model.decode_step(p, toks[:, 64], 64, cache, "full")
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(train_logits[:, -1]),
                               atol=2e-3, rtol=2e-3)


def test_prefill_then_decode_ssm():
    """Same continuity for a recurrent arch (state carry through decode)."""
    cfg = get_config("rwkv6-1.6b").smoke()
    model = build_model(cfg)
    p = model.init(KEY)
    toks = jax.random.randint(KEY, (2, 65), 0, cfg.vocab)
    train_logits, _ = model.train_logits(p, {"tokens": toks})
    cache = model.init_cache(2, 80)
    _, cache = model.prefill(p, {"tokens": toks[:, :64]}, cache, "full")
    dec_logits, _ = model.decode_step(p, toks[:, 64], 64, cache, "full")
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(train_logits[:, -1]),
                               atol=5e-3, rtol=5e-3)


def test_engine_generate_greedy_deterministic():
    cfg = get_config("granite-3-2b").smoke()
    model = build_model(cfg)
    p = model.init(KEY)
    eng = Engine(model, p, method="quoka")
    toks = np.asarray(jax.random.randint(KEY, (2, 48), 0, cfg.vocab))
    batch = eng.pad_prompt(toks)
    r1 = eng.generate(batch, 6)
    r2 = eng.generate(batch, 6)
    assert (r1.tokens == r2.tokens).all()
    assert r1.tokens.shape == (2, 6)
    assert r1.ttft_s > 0


def test_sampler_modes():
    logits = jnp.asarray([[0.0, 5.0, 1.0, -2.0]] * 3)
    assert (sample(logits, KEY, SamplerConfig()) == 1).all()
    t = sample(logits, KEY, SamplerConfig(temperature=1.0, top_k=2))
    assert bool(jnp.isin(t, jnp.asarray([1, 2])).all())
    t = sample(logits, KEY, SamplerConfig(temperature=1.0, top_p=0.5))
    assert (t == 1).all()


def test_sampler_top_p_degenerate_keeps_max():
    """When top_p keeps zero tokens (csum[0] >= p, cutoff_idx == 0) the
    max-prob token must always survive — for any p, including p ~ 0 and
    p = 1.0 where float cumsum rounding can push the cutoff out of range."""
    logits = jnp.asarray([[0.0, 5.0, 1.0, -2.0]] * 2)
    for p in (1e-9, 0.5, 1.0):
        for i in range(5):
            k = jax.random.fold_in(KEY, i)
            t = sample(logits, k, SamplerConfig(temperature=1.0, top_p=p))
            assert int(t.min()) >= 0 and int(t.max()) < 4
            if p <= 0.5:            # nucleus collapses to the argmax
                assert (t == 1).all(), (p, t)
    # uniform logits: every token ties for max; sampling must stay valid
    t = sample(jnp.zeros((3, 8)), KEY,
               SamplerConfig(temperature=1.0, top_p=1e-9))
    assert bool((t >= 0).all()) and bool((t < 8).all())


def test_pad_prompt_masks_pads_from_context():
    """Satellite regression: left-pad slots must carry pos == -1 — excluded
    from attention, selection scoring and the cache — so a padded prefill
    reproduces the unpadded forward at the last position."""
    cfg = get_config("granite-3-2b").smoke()
    model = build_model(cfg)
    p = model.init(KEY)
    toks = np.asarray(jax.random.randint(KEY, (2, 24), 3, cfg.vocab))
    eng = Engine(model, p, method="full")
    batch = eng.pad_prompt(toks)
    assert batch["tokens"].shape == (2, 32) and (batch["pad"] == 8).all()

    train_logits, _ = model.train_logits(p, {"tokens": jnp.asarray(toks)})
    cache = model.init_cache(2, 48)
    pf, cache = model.prefill(
        p, {"tokens": jnp.asarray(batch["tokens"]),
            "pad": jnp.asarray(batch["pad"])}, cache, "full")
    np.testing.assert_allclose(np.asarray(pf),
                               np.asarray(train_logits[:, -1]),
                               atol=2e-3, rtol=2e-3)
    # the cache itself marks pad slots invalid
    kv_pos = np.asarray(cache.stacks[0][0].kv.pos)      # (R, b, cap)
    assert (kv_pos[:, :, :8] == -1).all()
    assert (kv_pos[:, :, 8:32] >= 0).all()


def test_padded_generate_matches_unpadded_quoka():
    """Greedy generation from a padded prompt equals generation from the
    same prompt served unpadded (continuous path) — pads cannot skew
    QUOKA's query/key statistics."""
    from repro.serving.request import make_requests
    cfg = get_config("granite-3-2b").smoke()
    model = build_model(cfg)
    p = model.init(KEY)
    eng = Engine(model, p, method="quoka")
    prompt = np.asarray(jax.random.randint(KEY, (40,), 3, cfg.vocab),
                        np.int32)
    ref = eng.generate(eng.pad_prompt(prompt[None]), 5).tokens[0]
    res = eng.serve(make_requests([prompt], 5), block_size=16,
                    max_decode_batch=2)
    np.testing.assert_array_equal(res.tokens[0], ref)
