"""SelectionPlan (core/plan.py): the staged score -> select -> materialize
pipeline, block granularity, cross-layer reuse and the contiguous-gather
invariant the paged serving path relies on.

The sharded half runs in one subprocess under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (same pattern as
test_sharded_serving.py): plan indices built through the T-local shard_map
candidate path must be bit-identical to the meshless build.
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import hlo
from repro.configs import get_config
from repro.configs.base import QuokaConfig
from repro.core import plan as plan_mod
from repro.core.attention import NEG_INF
from repro.core.chunked_prefill import output_error
from repro.data.synthetic import structured_qkv
from repro.models.model import build_model
from repro.serving import pool as pl

KEY = jax.random.PRNGKey(0)
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# staged pipeline contract
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("granularity", [1, 16])
def test_staged_equals_fused(granularity):
    """build + materialize is exactly select, and the plan's static shape
    is plan_idx_shape's."""
    b, t, h, n_kv, d = 2, 64, 4, 2, 16
    q = jax.random.normal(KEY, (b, 16, h, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, t, n_kv, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, t, n_kv, d))
    pos = jnp.arange(t, dtype=jnp.int32)[None].repeat(b, 0)
    cfg = QuokaConfig(budget=32, n_queries=8, keep_first=4,
                      granularity=granularity)
    start = jnp.asarray(48)
    pln = plan_mod.build("quoka", q, k, pos, start, cfg)
    assert pln.idx.shape == plan_mod.plan_idx_shape(cfg, b, n_kv, t)
    sel = plan_mod.materialize(pln, k, v, pos, start, cfg)
    ref = plan_mod.select("quoka", q, k, v, pos, start, cfg)
    for a, r in zip(sel, ref):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(r))


def test_block_plan_is_shared_across_heads():
    """g > 1 plans carry BLOCK ids shared by every KV head (a per-head
    block plan could not be a block-table sub-view), and materialize
    broadcasts identical per-token metadata to each head."""
    b, t, n_kv, d = 1, 64, 2, 8
    k = jax.random.normal(KEY, (b, t, n_kv, d))
    pos = jnp.arange(t, dtype=jnp.int32)[None]
    cfg = QuokaConfig(granularity=8, keep_first=0)
    scores = jax.random.normal(jax.random.fold_in(KEY, 3), (b, n_kv, t))
    pln = plan_mod.plan_from_scores(scores.astype(jnp.float32), pos, cfg,
                                    budget=32)
    assert pln.idx.shape == (b, 4)                       # blocks, not tokens
    sel = plan_mod.materialize(pln, k, k, pos, jnp.asarray(t), cfg)
    np.testing.assert_array_equal(np.asarray(sel.pos[0, 0]),
                                  np.asarray(sel.pos[0, 1]))


def test_block_full_budget_matches_dense():
    """Equivalence gate at block granularity: budget >= T selects every
    prior block, so chunked output == dense causal attention."""
    q = jax.random.normal(KEY, (1, 128, 4, 16))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 128, 2, 16))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 128, 2, 16))
    cfg = QuokaConfig(chunk_size=32, budget=128, granularity=16,
                      n_queries=8, keep_first=0)
    assert float(output_error(q, k, v, cfg, "quoka")) < 2e-3


def test_block_union_across_chunk_boundary():
    """A block straddling the chunk boundary is selected WHOLE; its
    not-yet-prior tokens come back as pos = -1 budget padding with the
    slot index re-derived at materialize time."""
    t, g = 32, 8
    pos = jnp.arange(t, dtype=jnp.int32)[None]
    start = 12                                 # boundary inside block 1
    tok = jnp.arange(t)
    scores = jnp.where(tok < start, jnp.where(tok >= 8, 5.0, 1.0),
                       NEG_INF)[None, None, :].astype(jnp.float32)
    cfg = QuokaConfig(granularity=g, keep_first=0)
    pln = plan_mod.plan_from_scores(scores, pos, cfg, budget=16)
    # block 1 (max 5.0) then block 0 (max 1.0); blocks 2/3 are all-invalid
    np.testing.assert_array_equal(np.asarray(pln.idx), [[1, 0]])
    k = jax.random.normal(KEY, (1, t, 1, 4))
    sel = plan_mod.materialize(pln, k, k, pos, jnp.asarray(start), cfg)
    np.testing.assert_array_equal(
        np.asarray(sel.pos[0, 0]),
        [8, 9, 10, 11, -1, -1, -1, -1, 0, 1, 2, 3, 4, 5, 6, 7])
    got = np.asarray(sel.idx[0, 0])
    want = np.asarray([8, 9, 10, 11, -1, -1, -1, -1] + list(range(8)))
    np.testing.assert_array_equal(got, want)
    valid = want >= 0
    np.testing.assert_allclose(np.asarray(sel.k[0, valid, 0]),
                               np.asarray(k[0, want[valid], 0]))


def test_block_granularity_accuracy_delta_bounded():
    """Accuracy proxy (paper eq. (4)): selecting whole 16-token blocks
    instead of tokens costs a bounded output-error delta at half budget."""
    q, k, v = structured_qkv(jax.random.PRNGKey(3), 2, 512, 8, 2, 32)
    tok = QuokaConfig(chunk_size=128, budget=256, n_queries=16, keep_first=4)
    blk = dataclasses.replace(tok, granularity=16)
    err_tok = float(output_error(q, k, v, tok, "quoka"))
    err_blk = float(output_error(q, k, v, blk, "quoka"))
    assert err_blk < 0.5, (err_tok, err_blk)
    assert err_blk <= err_tok + 0.15, (err_tok, err_blk)


# ---------------------------------------------------------------------------
# cross-layer reuse
# ---------------------------------------------------------------------------

def test_refresh_cadence_and_corrections():
    """refresh rebuilds at layer % interval == 0 and at correction layers,
    reuses the carried indices in between."""
    shape = (1, 4)
    cfg = QuokaConfig(reuse_interval=2, correction_layers=(3,))
    mk = lambda tag: (lambda: plan_mod.SelectionPlan(
        idx=jnp.full(shape, tag, jnp.int32)))
    carry = plan_mod.empty_carry(shape)
    seen = []
    for li in range(6):
        pln, carry = plan_mod.refresh(carry, li, cfg, mk(li))
        assert carry is not None and bool(carry.valid)
        seen.append(int(pln.idx[0, 0]))
    assert seen == [0, 0, 2, 3, 4, 4]
    # no carry (reuse disabled / unsupported geometry): build every layer
    pln, carry = plan_mod.refresh(None, 5, cfg, mk(7))
    assert carry is None and int(pln.idx[0, 0]) == 7


GRANITE = get_config("granite-3-2b").smoke(n_layers=4)


def _quoka_variant(**kw):
    return dataclasses.replace(
        GRANITE, quoka=dataclasses.replace(GRANITE.quoka, **kw))


@pytest.fixture(scope="module")
def granite_params():
    # params do not depend on QuokaConfig: one init serves every variant
    return build_model(GRANITE).init(jax.random.PRNGKey(0))


def _prefill_logits(cfg, params, toks):
    model = build_model(cfg)
    cache = model.init_cache(toks.shape[0], toks.shape[1])
    logits, _ = model.prefill(params, {"tokens": toks}, cache, "quoka")
    return np.asarray(logits)


@pytest.mark.slow
def test_corrections_everywhere_equal_interval_one(granite_params):
    """reuse_interval=4 with correction layers covering EVERY layer must
    rebuild everywhere — token-identical to reuse_interval=1."""
    toks = jax.random.randint(KEY, (2, 96), 3, GRANITE.vocab)
    base = _prefill_logits(_quoka_variant(reuse_interval=1), granite_params,
                           toks)
    corr = _prefill_logits(
        _quoka_variant(reuse_interval=4, correction_layers=(0, 1, 2, 3)),
        granite_params, toks)
    np.testing.assert_allclose(corr, base, atol=1e-6, rtol=1e-6)


@pytest.mark.slow
def test_reuse_interval_engages_and_decodes(granite_params):
    """Plans reused across layers actually change the computation (layers
    1..3 consume layer 0's plan), and the decode path carries plans too."""
    from repro.serving.engine import Engine
    toks = jax.random.randint(KEY, (2, 96), 3, GRANITE.vocab)
    base = _prefill_logits(_quoka_variant(reuse_interval=1), granite_params,
                           toks)
    reused = _prefill_logits(_quoka_variant(reuse_interval=4),
                             granite_params, toks)
    assert not np.allclose(reused, base, atol=1e-6), \
        "reuse_interval=4 produced bit-identical logits: carry not engaged"
    cfg = _quoka_variant(reuse_interval=2)
    eng = Engine(build_model(cfg), granite_params, method="quoka")
    out = eng.generate(eng.pad_prompt(np.asarray(toks)), 4)
    tok = np.asarray(out.tokens)
    assert tok.shape == (2, 4)                           # the new tokens
    assert (tok >= 0).all() and (tok < cfg.vocab).all()


# ---------------------------------------------------------------------------
# paged pool: plans as block-table sub-views + the contiguity invariant
# ---------------------------------------------------------------------------

def _pool_data(num_blocks, block_size, n_kv, d):
    k = jax.random.normal(KEY, (1, num_blocks, block_size, n_kv, d))
    pos = jnp.arange(num_blocks * block_size, dtype=jnp.int32).reshape(
        1, num_blocks, block_size)
    return {"k": k, "pos": pos}


def test_gather_blocks_is_block_table_subview():
    bs, n_kv, d = 4, 2, 4
    data = _pool_data(6, bs, n_kv, d)
    table = jnp.asarray([[0, 1, 2], [3, 4, -1]], jnp.int32)
    ids = jnp.asarray([[2, 0], [1, -1]], jnp.int32)      # logical, -1 pad
    out = pl.gather_blocks(data, table, ids, 6, bs)
    assert out["k"].shape == (1, 2, 2 * bs, n_kv, d)
    np.testing.assert_allclose(np.asarray(out["k"][0, 0, :bs]),
                               np.asarray(data["k"][0, 2]))
    np.testing.assert_allclose(np.asarray(out["k"][0, 0, bs:]),
                               np.asarray(data["k"][0, 0]))
    np.testing.assert_allclose(np.asarray(out["k"][0, 1, :bs]),
                               np.asarray(data["k"][0, 4]))
    # padding ids read as pos = -1 (and zero payload), like empty table slots
    assert (np.asarray(out["pos"][0, 1, bs:]) == -1).all()
    assert (np.asarray(out["k"][0, 1, bs:]) == 0).all()


def test_materialize_hlo_contiguous_block_slices():
    """The invariant the paged path relies on: at g > 1 every KV-payload
    gather in the compiled module moves whole g-token slabs — slice_sizes
    span the block extent, no per-token gather."""
    b, t, n_kv, d, g = 2, 128, 4, 64, 16
    cfg = QuokaConfig(granularity=g, keep_first=0)
    k = jax.random.normal(KEY, (b, t, n_kv, d))
    pos = jnp.arange(t, dtype=jnp.int32)[None].repeat(b, 0)
    idx = jnp.asarray([[0, 2, 5, -1]] * b, jnp.int32)
    fn = jax.jit(lambda i, k, v: plan_mod.materialize(
        plan_mod.SelectionPlan(idx=i), k, v, pos, jnp.asarray(t), cfg))
    txt = fn.lower(idx, k, k).compile().as_text()
    sizes = hlo.gather_slice_sizes(txt)
    payload = [s for s in sizes if d in s]
    assert payload, f"no KV-payload gather found: {sizes}"
    assert all(g in s for s in payload), \
        f"per-token gather on the KV payload: {sizes}"


def test_gather_blocks_hlo_contiguous_block_slices():
    """Same invariant on the pool side: gather_blocks lowers to one
    dynamic block_size-row slice per selected block for every pool leaf."""
    bs, n_kv, d = 16, 2, 8
    data = _pool_data(8, bs, n_kv, d)
    table = jnp.zeros((2, 4), jnp.int32)
    ids = jnp.zeros((2, 2), jnp.int32)
    fn = jax.jit(lambda dat, tb, bi: pl.gather_blocks(dat, tb, bi, 8, bs))
    txt = fn.lower(data, table, ids).compile().as_text()
    sizes = hlo.gather_slice_sizes(txt)
    payload = [s for s in sizes if len(s) >= 3]          # pool data leaves
    assert payload, f"no pool-leaf gather found: {sizes}"
    assert all(bs in s for s in payload), \
        f"sub-block gather on a pool leaf: {sizes}"


def test_serve_rejects_grid_misaligned_block_size(granite_params):
    """make_serve_state must refuse a pool whose block grid the selection
    grid does not divide — block plans could not be table sub-views."""
    from repro.serving.engine import Engine
    from repro.serving.request import make_requests
    eng = Engine(build_model(_quoka_variant(granularity=12)),
                 granite_params, method="quoka")
    reqs = make_requests([np.arange(3, 35, dtype=np.int32)], 4)
    with pytest.raises(ValueError, match="granularity"):
        eng.make_serve_state(reqs, block_size=16)


# ---------------------------------------------------------------------------
# sharded plan candidates == meshless, bit for bit
# ---------------------------------------------------------------------------

SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import sys
    sys.path.insert(0, __SRC__)
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.analysis import hlo
    from repro.configs.base import QuokaConfig
    from repro.core import plan as plan_mod
    from repro.core import quoka as qk
    from repro.launch.mesh import make_host_mesh
    from repro.sharding import ctx as shctx

    b, t, h, n_kv, d = 2, 128, 8, 2, 32
    q = jax.random.normal(jax.random.PRNGKey(1), (b, 16, h, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(2), (b, t, n_kv, d),
                          jnp.float32)
    pos = jnp.arange(t, dtype=jnp.int32)[None].repeat(b, 0)
    start = jnp.asarray(96)
    mesh = make_host_mesh(model=4, data=2)
    out = {}
    for g, budget in ((1, 48), (16, 64)):
        cfg = QuokaConfig(budget=budget, n_queries=8, keep_first=4,
                          granularity=g)
        ref = plan_mod.build("quoka", q, k, pos, start, cfg)
        snap = shctx.get_policy()
        shctx.set_policy(mesh, ("data",))
        try:
            with mesh:
                assert qk._tp_route(k, cfg) is not None, "TP path idle"
                got = plan_mod.build("quoka", q, k, pos, start, cfg)
                fn = jax.jit(lambda q, k, p, c=cfg: plan_mod.build(
                    "quoka", q, k, p, start, c).idx)
                txt = fn.lower(q, k, pos).compile().as_text()
        finally:
            shctx.restore_policy(snap)
        out[f"g{g}/bit_exact"] = bool(np.array_equal(
            np.asarray(ref.idx), np.asarray(got.idx)))
        out[f"g{g}/allgather_bytes"] = hlo.collective_bytes(txt).get(
            "all-gather", 0)
    out["k_cache_bytes"] = b * t * n_kv * d * 4
    print("RESULT", json.dumps(out))
""")


@pytest.fixture(scope="module")
def sharded_plan_result():
    code = SUBPROC.replace("__SRC__", repr(os.path.abspath(SRC)))
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=600)
    for line in res.stdout.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise AssertionError(f"subprocess failed:\n{res.stderr[-3000:]}")


@pytest.mark.slow
@pytest.mark.parametrize("g", [1, 16])
def test_sharded_plan_candidates_bit_exact(sharded_plan_result, g):
    """The T-local shard_map candidate merge returns the SAME plan indices
    as the meshless build — token slots at g=1, block ids at g=16 — and
    moves only candidates (tiny all-gather), never the K cache."""
    r = sharded_plan_result
    assert r[f"g{g}/bit_exact"], r
    ag = r[f"g{g}/allgather_bytes"]
    assert ag > 0, "shard_map candidate merge did not engage"
    assert ag < r["k_cache_bytes"] / 4, r
