"""Per-chunk dispatch (production serving) vs monolithic scan prefill must
produce identical results — the §Perf A3 restructuring's correctness gate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import build_model

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ["granite-3-2b", "gemma3-27b", "zamba2-7b",
                                  "deepseek-v3-671b"])
@pytest.mark.parametrize("method", ["full", "quoka"])
def test_chunkwise_equals_monolithic(arch, method):
    cfg = get_config(arch).smoke()
    model = build_model(cfg)
    p = model.init(KEY)
    toks = jax.random.randint(KEY, (2, 64), 0, cfg.vocab)
    bcp = cfg.quoka.chunk_size

    cache1 = model.init_cache(2, 96)
    logits_mono, cache1 = model.prefill(p, {"tokens": toks}, cache1, method)

    cache2 = model.init_cache(2, 96)
    last_h = None
    for c0 in range(0, 64, bcp):
        chunk = toks[:, c0:c0 + bcp]
        last_h, cache2 = model.prefill_chunk(p, {"tokens": chunk},
                                             jnp.asarray(c0), cache2, method)
    logits_chunk = model._readout(p, last_h[:, None, :])[:, 0]
    np.testing.assert_allclose(np.asarray(logits_chunk),
                               np.asarray(logits_mono),
                               atol=2e-3, rtol=2e-3)
    # caches identical too (positions and KV rows)
    for a, b in zip(jax.tree.leaves(cache1), jax.tree.leaves(cache2)):
        if a.dtype == jnp.int32:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
