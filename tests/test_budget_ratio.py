"""Paper Table 2: B_SA as a fraction of the context length."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import QuokaConfig
from repro.core.chunked_prefill import chunked_sparse_attention, output_error
from repro.core.plan import select
from repro.core.selection import resolve_budget
from repro.data.synthetic import structured_qkv

KEY = jax.random.PRNGKey(0)


def test_resolve_budget():
    assert resolve_budget(QuokaConfig(budget=77), 1000) == 77
    assert resolve_budget(QuokaConfig(budget_ratio=0.25), 1000) == 250
    assert resolve_budget(QuokaConfig(budget_ratio=0.001, keep_first=4),
                          100) == 5     # floor at keep_first + 1


def test_resolve_budget_floors_to_selection_grid():
    """Regression: a ratio budget straddling the B_CP/pool block grid must
    be floored to it HERE — callers (scheduler/engine/plan) no longer
    round."""
    # 0.25 * 1000 = 250 straddles a 16-token grid -> 240
    assert resolve_budget(QuokaConfig(budget_ratio=0.25, granularity=16),
                          1000) == 240
    # fixed budgets floor too, but never below one block
    assert resolve_budget(QuokaConfig(budget=77, granularity=16), 1000) == 64
    assert resolve_budget(QuokaConfig(budget=7, granularity=16), 1000) == 16
    # granularity 1 is the identity (legacy behaviour pinned above)
    assert resolve_budget(QuokaConfig(budget_ratio=0.25, granularity=1),
                          1000) == 250


def test_ratio_budget_selects_fraction():
    q = jax.random.normal(KEY, (1, 16, 4, 8))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 256, 2, 8))
    pos = jnp.arange(256)[None]
    sel = select("quoka", q, k, k, pos, jnp.asarray(200),
                 QuokaConfig(budget_ratio=0.25, n_queries=8))
    assert sel.pos.shape[-1] == 64      # 25% of 256


def test_quarter_budget_accuracy_tracks_fixed(paper_table2=True):
    """25%-of-context budget stays close to dense (the paper's Table 2
    finding: 'accuracy loss remains very limited even at long sequences')."""
    q, k, v = structured_qkv(jax.random.PRNGKey(3), 2, 512, 8, 2, 32)
    errs = {}
    for name, cfg in {
        "quarter": QuokaConfig(chunk_size=128, budget_ratio=0.25,
                               n_queries=16, keep_first=4),
        "full_budget": QuokaConfig(chunk_size=128, budget=512,
                                   n_queries=16, keep_first=4),
    }.items():
        errs[name] = float(output_error(q, k, v, cfg, "quoka"))
    assert errs["quarter"] < 0.5, errs
    assert errs["full_budget"] < 0.05, errs
