"""Roofline + HLO-cost analyzer unit tests (on hand-built HLO and live
lowerings without any forced device count)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import hlo_cost, roofline
from repro.configs import get_config

HLO = """
HloModule test

%body.1 (p: (s32[], f32[128,128])) -> (s32[], f32[128,128]) {
  %p = (s32[], f32[128,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[128,128]{1,0} get-tuple-element(%p), index=1
  %w = f32[128,128]{1,0} constant({...})
  %dot.5 = f32[128,128]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[128,128]{1,0} all-reduce(%dot.5), replica_groups={}
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[128,128]) tuple(%ni, %ar)
}

%cond.1 (p2: (s32[], f32[128,128])) -> pred[] {
  %p2 = (s32[], f32[128,128]) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(%i2, %n), direction=LT
}

ENTRY %main (a: f32[128,128]) -> f32[128,128] {
  %a = f32[128,128]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %tup = (s32[], f32[128,128]) tuple(%zero, %a)
  %w8 = (s32[], f32[128,128]) while(%tup), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"7"}}
  ROOT %out = f32[128,128]{1,0} get-tuple-element(%w8), index=1
}
"""


def test_hlo_cost_trip_multiplication():
    tot = hlo_cost.analyze_text(HLO)
    assert tot["flops"] == 7 * 2 * 128 ** 3
    # all-reduce: result 64KB * factor 2 * 7 trips
    assert tot["coll_all-reduce"] == 7 * 2 * 128 * 128 * 4
    assert tot["coll_total"] == tot["coll_all-reduce"]


def test_hlo_cost_on_live_lowering():
    """Analyzer FLOPs match a known matmul-in-scan on this process's CPU."""
    n = 64
    def f(x, w):
        def body(h, _):
            return h @ w, None
        return jax.lax.scan(body, x, None, length=5)[0]
    c = jax.jit(f).lower(jnp.ones((n, n)), jnp.ones((n, n))).compile()
    tot = hlo_cost.analyze_text(c.as_text())
    assert abs(tot["flops"] - 5 * 2 * n ** 3) / (5 * 2 * n ** 3) < 0.05


def test_roofline_terms_and_bottleneck():
    r = roofline.analyse(
        "a", "s", "16x16", 256,
        {"flops": roofline.PEAK_FLOPS, "bytes": roofline.HBM_BW / 2},
        {"coll_total": roofline.LINK_BW / 4}, model_flops=1e15)
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 0.5) < 1e-9
    assert abs(r.t_collective - 0.25) < 1e-9
    assert r.bottleneck == "compute"


def test_model_flops_sane():
    cfg = get_config("granite-3-2b")
    mf_train = roofline.model_flops(cfg, "train", 256, 4096)
    assert mf_train > 6 * cfg.param_count() * 256 * 4096 * 0.9
    mf_dec = roofline.model_flops(cfg, "decode", 128, 32768)
    assert mf_dec < mf_train / 1000
