"""Sharding-spec correctness + an actual small-mesh SPMD lowering test run
in a subprocess (so the 8-device host flag never leaks into this process)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED, get_config
from repro.models.model import build_model
from repro.sharding import specs as sh

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


class FakeMesh:
    """Just enough Mesh surface for spec generation (no devices needed)."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


@pytest.mark.parametrize("arch", list(ASSIGNED))
def test_param_specs_divide_evenly(arch):
    """Every sharded axis must divide its dim on the production mesh."""
    cfg = get_config(arch)
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    mesh = FakeMesh({"data": 16, "model": 16})
    spec_tree = sh.param_specs(cfg, params, mesh)

    def check(path, leaf, spec):
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            size = sh._axes_size(mesh, ax)
            assert dim % size == 0, (path, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(
        check, params, spec_tree,
        is_leaf=lambda x: isinstance(x, P) or hasattr(x, "shape"))


def test_kv_heads_not_split_through(monkeypatch):
    """granite kv=8 on a 16-way model axis: wk/wv must NOT shard on model."""
    cfg = get_config("granite-3-2b")
    model = build_model(cfg)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    mesh = FakeMesh({"data": 16, "model": 16})
    spec_tree = sh.param_specs(cfg, params, mesh)
    flat = jax.tree_util.tree_flatten_with_path(
        spec_tree, is_leaf=lambda x: isinstance(x, P))[0]
    for path, spec in flat:
        s = sh._path_str(path)
        if "/wk/w" in s or "/wv/w" in s:
            assert "model" not in tuple(spec), (s, spec)
        if "/wq/w" in s:
            assert "model" in tuple(spec), (s, spec)   # 32 q heads divide


def test_cache_specs_long_context_shards_sequence():
    cfg = get_config("zamba2-7b")
    model = build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(1, 4096))
    mesh = FakeMesh({"data": 16, "model": 16})
    spec_tree = sh.cache_specs(cfg, cache, mesh)
    flat = jax.tree_util.tree_flatten_with_path(
        spec_tree, is_leaf=lambda x: isinstance(x, P))[0]
    kv_specs = [spec for path, spec in flat
                if sh._path_str(path).endswith("/k")]
    assert kv_specs, "no kv cache leaves found"
    for spec in kv_specs:
        assert tuple(spec)[2] == "data", spec     # sequence axis sharded


SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, __SRC__)
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.launch.mesh import make_host_mesh
    from repro.models.model import build_model
    from repro.sharding import specs as sh
    from repro.training import loop as tl, optimizer as opt

    cfg = get_config("olmoe-1b-7b").smoke(n_heads=4, n_kv_heads=2)
    model = build_model(cfg)
    mesh = make_host_mesh(model=2, data=4)
    key = jax.random.PRNGKey(0)
    state_s = jax.eval_shape(lambda k: tl.init_state(model, k), key)
    batch_s = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
    step = tl.make_train_step(model, opt.OptimizerConfig())
    with mesh:
        pspec = sh.param_specs(cfg, state_s.params, mesh)
        st = tl.TrainState(params=pspec,
                           opt=opt.OptState(step=jax.sharding.PartitionSpec(),
                                            mu=pspec, nu=pspec))
        in_sh = (sh.to_shardings(mesh, st),
                 sh.to_shardings(mesh, sh.batch_spec(cfg, batch_s, mesh)))
        compiled = jax.jit(step, in_shardings=in_sh).lower(
            state_s, batch_s).compile()
    print("COMPILED_OK", compiled.cost_analysis() is not None)
""")


def test_spmd_lowering_on_host_mesh():
    """End-to-end: the production sharding stack compiles a real SPMD module
    on an 8-device host mesh (subprocess keeps the flag isolated)."""
    code = SUBPROC.replace("__SRC__", repr(os.path.abspath(SRC)))
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=420)
    assert "COMPILED_OK" in res.stdout, res.stderr[-2000:]
