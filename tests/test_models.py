"""Sequence-mixer correctness: chunked scans vs naive recurrences; MLA
absorbed vs explicit; MoE dispatch equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import (MLAConfig, ModelConfig, MoEConfig,
                                QuokaConfig, RWKVConfig, SSMConfig)
from repro.models import mamba2, moe, rwkv6
from repro.models.blocks import MLABlock
from repro.serving.cache import MambaCache, RWKVCache

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# RWKV6: chunked parallel form == naive per-token recurrence
# ---------------------------------------------------------------------------

def _naive_rwkv(r, k, v, lw, u, state):
    """o_t = r_t (S_{t-1} + (u*k_t) v_t^T);  S_t = diag(w_t) S + k_t v_t^T."""
    b, t, h, d = r.shape
    outs = []
    S = np.asarray(state, np.float64)
    rn, kn, vn = (np.asarray(x, np.float64) for x in (r, k, v))
    wn = np.exp(np.asarray(lw, np.float64))
    un = np.asarray(u, np.float64)
    for i in range(t):
        bonus = np.einsum("bhd,bhe->bhde", un[None] * kn[:, i], vn[:, i])
        o = np.einsum("bhd,bhde->bhe", rn[:, i], S + bonus)
        outs.append(o)
        S = wn[:, i][..., None] * S + np.einsum(
            "bhd,bhe->bhde", kn[:, i], vn[:, i])
    return np.stack(outs, axis=1), S


def test_rwkv_chunked_matches_naive():
    b, t, h, d = 2, 37, 2, 8          # non-multiple of CHUNK on purpose
    r = jax.random.normal(KEY, (b, t, h, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, t, h, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, t, h, d))
    lw = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 3),
                                    (b, t, h, d)) - 1.0)
    u = jax.random.normal(jax.random.fold_in(KEY, 4), (h, d)) * 0.1
    S0 = jax.random.normal(jax.random.fold_in(KEY, 5), (b, h, d, d)) * 0.1

    # pad to CHUNK multiple like time_mix does
    pad = (-t) % rwkv6.CHUNK
    zf = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
    out, S = rwkv6._time_mix_chunked(
        zf(r.astype(jnp.float32)), zf(k.astype(jnp.float32)),
        zf(v.astype(jnp.float32)), zf(lw.astype(jnp.float32)),
        u, S0.astype(jnp.float32))
    want, S_want = _naive_rwkv(r, k, v, lw, u, S0)
    np.testing.assert_allclose(np.asarray(out)[:, :t], want,
                               atol=1e-3, rtol=1e-3)


def test_rwkv_state_carry_equals_full_segment():
    """Processing [x1; x2] in two calls with carried cache == one call."""
    cfg = get_config("rwkv6-1.6b").smoke()
    p = rwkv6.rwkv_init(KEY, cfg)
    b, t, d = 2, 64, cfg.d_model
    x = jax.random.normal(KEY, (b, t, d))
    c0 = rwkv6.rwkv_cache_init(b, cfg, jnp.float32)
    y_full, _, _ = rwkv6.time_mix(p["tm"], x, c0.shift_tm, c0.wkv, cfg)
    y1, sh1, wkv1 = rwkv6.time_mix(p["tm"], x[:, :32], c0.shift_tm, c0.wkv, cfg)
    y2, _, _ = rwkv6.time_mix(p["tm"], x[:, 32:], sh1, wkv1, cfg)
    np.testing.assert_allclose(np.asarray(y_full[:, 32:]), np.asarray(y2),
                               atol=2e-3, rtol=2e-3)


# ---------------------------------------------------------------------------
# Mamba2 SSD: chunked form == naive recurrence; segment carry consistency
# ---------------------------------------------------------------------------

def _naive_ssd(x, dt, la, B, C, state):
    b, t, h, p = x.shape
    S = np.asarray(state, np.float64)
    xs, dts, Bs, Cs = (np.asarray(a, np.float64) for a in (x, dt, B, C))
    an = np.exp(np.asarray(la, np.float64))
    ys = []
    for i in range(t):
        S = an[:, i][:, :, None, None] * S + np.einsum(
            "bh,bhp,bn->bhpn", dts[:, i], xs[:, i], Bs[:, i])
        ys.append(np.einsum("bhpn,bn->bhp", S, Cs[:, i]))
    return np.stack(ys, axis=1), S


def test_mamba_chunked_matches_naive():
    b, t, h, p, n = 2, 70, 2, 4, 8
    x = jax.random.normal(KEY, (b, t, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 1), (b, t, h)))
    la = -dt * 0.5
    B = jax.random.normal(jax.random.fold_in(KEY, 2), (b, t, n))
    C = jax.random.normal(jax.random.fold_in(KEY, 3), (b, t, n))
    S0 = jnp.zeros((b, h, p, n))
    pad = (-t) % mamba2.CHUNK
    pf = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
    y, S = mamba2._ssd_chunked(pf(x), pf(dt), pf(la), pf(B), pf(C), S0)
    want, _ = _naive_ssd(x, dt, la, B, C, S0)
    np.testing.assert_allclose(np.asarray(y[:, :t]), want,
                               atol=1e-3, rtol=1e-3)


def test_mamba_segment_carry():
    cfg = get_config("zamba2-7b").smoke()
    p = mamba2.mamba_init(KEY, cfg)
    b, t = 2, 64
    x = jax.random.normal(KEY, (b, t, cfg.d_model))
    c0 = mamba2.mamba_cache_init(b, cfg, jnp.float32)
    y_full, _ = mamba2.mamba_apply(p, x, c0, cfg)
    y1, c1 = mamba2.mamba_apply(p, x[:, :32], c0, cfg)
    y2, _ = mamba2.mamba_apply(p, x[:, 32:], c1, cfg)
    np.testing.assert_allclose(np.asarray(y_full[:, 32:]), np.asarray(y2),
                               atol=2e-3, rtol=2e-3)
    # decode: one token at a time must agree too
    y3, c3 = mamba2.mamba_apply(p, x[:, 32:33], c1, cfg)
    np.testing.assert_allclose(np.asarray(y_full[:, 32:33]), np.asarray(y3),
                               atol=2e-3, rtol=2e-3)


# ---------------------------------------------------------------------------
# MoE: capacity dispatch ≈ dense gating when nothing is dropped
# ---------------------------------------------------------------------------

def test_moe_capacity_matches_dense_at_high_capacity():
    cfg_d = get_config("olmoe-1b-7b").smoke()
    e = dataclasses.replace(cfg_d.moe, dispatch="dense")
    cfg_dense = dataclasses.replace(cfg_d, moe=e)
    e2 = dataclasses.replace(cfg_d.moe, dispatch="capacity",
                             capacity_factor=float(cfg_d.moe.n_experts))
    cfg_cap = dataclasses.replace(cfg_d, moe=e2)
    p = moe.moe_init(KEY, cfg_dense)
    x = jax.random.normal(KEY, (2, 16, cfg_d.d_model))
    y_dense = moe.moe_apply(p, x, cfg_dense, {})
    y_cap = moe.moe_apply(p, x, cfg_cap, {})
    np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_cap),
                               atol=2e-4, rtol=2e-4)


def test_moe_aux_loss_accumulates():
    cfg = get_config("olmoe-1b-7b").smoke()
    p = moe.moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model))
    ctx = {}
    moe.moe_apply(p, x, cfg, ctx)
    assert "aux_loss" in ctx and float(ctx["aux_loss"]) > 0


# ---------------------------------------------------------------------------
# MLA: absorbed latent attention == explicit decompressed attention
# ---------------------------------------------------------------------------

def test_mla_absorbed_equals_explicit():
    cfg = get_config("deepseek-v3-671b").smoke()
    blk = MLABlock(cfg, "mla")
    p = blk.init(KEY)
    b, t = 2, 32
    x = jax.random.normal(KEY, (b, t, cfg.d_model)) * 0.1
    pos = jnp.arange(t)[None].repeat(b, 0)
    h = blk.norm(p["ln1"], x)
    q_abs, q_rope = blk._queries(p, h, pos)
    ckv, kr = blk._latent_kv(p, h, pos)
    from repro.core.attention import position_mask
    mask = position_mask(pos, pos, causal=True)
    got = blk._absorbed_attention(p, q_abs, q_rope, ckv, kr, mask)
    # explicit: decompress k/v per head, standard attention
    m = cfg.mla
    cq = jax.nn.standardize  # noqa: F841 (unused; clarity)
    k_nope = jnp.einsum("btr,rhn->bthn", ckv, p["wk_b"])
    v_full = jnp.einsum("btr,rhv->bthv", ckv, p["wv_b"])
    # recompute q_nope explicitly
    from repro.models.layers import linear, rmsnorm, rope as rope_fn
    cqv = rmsnorm(p["q_ln"], linear(p["wq_a"], h), cfg.norm_eps)
    q = linear(p["wq_b"], cqv).reshape(b, t, cfg.n_heads,
                                       m.qk_nope_dim + m.qk_rope_dim)
    q_nope = q[..., :m.qk_nope_dim]
    kr_b = jnp.broadcast_to(kr[:, :, None, :],
                            (b, t, cfg.n_heads, m.qk_rope_dim))
    q_full = jnp.concatenate([q_nope, rope_fn(q[..., m.qk_nope_dim:], pos,
                                              cfg.rope_theta)], -1)
    k_full = jnp.concatenate([k_nope, kr_b], -1)
    from repro.core.attention import dense_attention
    want = dense_attention(q_full, k_full, v_full, mask, scale=blk.scale)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(want.reshape(b, t, -1)),
                               atol=2e-4, rtol=2e-3)
