"""Continuous-batching subsystem: paged-pool invariants (no block leaked or
double-allocated across admit/evict cycles) and the greedy-parity gate —
tokens from Engine.serve() under continuous batching must exactly match
per-request Engine.generate() for the same prompts."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import build_model
from repro.serving.engine import Engine
from repro.serving.pool import PagedKVCache, blocks_for_request
from repro.serving.request import make_requests

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_config("granite-3-2b").smoke()
    model = build_model(cfg)
    return cfg, model, model.init(KEY)


def test_pool_alloc_free_invariants(smoke_model):
    _, model, _ = smoke_model
    pool = PagedKVCache(model, num_blocks=12, block_size=16)
    rng = np.random.default_rng(0)
    held = {}
    for step in range(200):                      # admit/evict cycles
        if held and (rng.random() < 0.5 or pool.num_free < 3):
            rid = rng.choice(list(held))
            pool.free(int(rid))
            del held[int(rid)]
        else:
            rid, n = step, int(rng.integers(1, 4))
            if pool.can_alloc(n):
                blocks = pool.alloc(rid, n)
                assert len(blocks) == n
                held[rid] = blocks
        pool.check_invariants()
    for rid in list(held):
        pool.free(rid)
    pool.check_invariants()
    assert pool.num_free == 12

    pool.alloc(0, 2)
    with pytest.raises(RuntimeError):
        pool.alloc(0, 1)                         # double-allocate a request
    with pytest.raises(RuntimeError):
        pool.alloc(1, 11)                        # beyond capacity
    pool.free(0)
    with pytest.raises(KeyError):
        pool.free(0)                             # double free


def test_pool_rejects_recurrent_archs():
    cfg = get_config("rwkv6-1.6b").smoke()
    model = build_model(cfg)
    with pytest.raises(ValueError, match="unsupported"):
        PagedKVCache(model, num_blocks=4, block_size=16)


def test_blocks_for_request_covers_padded_prompt():
    # prompt 17, chunk 32: prefill writes the whole padded chunk (32 slots)
    assert blocks_for_request(17, 1, chunk_size=32, block_size=8) == 4
    # decode span dominates when max_new is large
    assert blocks_for_request(16, 33, chunk_size=16, block_size=16) == 4


@pytest.mark.parametrize("method", ["full", "quoka"])
def test_continuous_greedy_parity(smoke_model, method):
    """serve() == per-request generate(), token for token, including a
    ragged (non-chunk-multiple) prompt that exercises tail-chunk padding."""
    cfg, model, p = smoke_model
    eng = Engine(model, p, method=method)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(3, cfg.vocab, (n,)).astype(np.int32)
               for n in (16, 48, 32, 24)]
    refs = [eng.generate(eng.pad_prompt(pr[None]), 6).tokens[0]
            for pr in prompts]
    res = eng.serve(make_requests(prompts, 6), block_size=16,
                    max_decode_batch=4, max_prefill_tokens=32)
    for i, ref in enumerate(refs):
        np.testing.assert_array_equal(res.tokens[i], ref)
    assert all(t > 0 for t in res.ttft_s.values())
    assert 0.0 < res.occupancy <= 1.0


def test_continuous_queueing_small_pool(smoke_model):
    """A pool that fits ~one request forces admission queueing; everything
    still completes and every block returns to the free list (asserted
    inside serve())."""
    cfg, model, p = smoke_model
    eng = Engine(model, p, method="quoka")
    rng = np.random.default_rng(5)
    prompts = [rng.integers(3, cfg.vocab, (32,)).astype(np.int32)
               for _ in range(3)]
    res = eng.serve(make_requests(prompts, 4), block_size=16, num_blocks=3,
                    max_decode_batch=4)
    assert sorted(res.tokens) == [0, 1, 2]
    assert all(len(v) == 4 for v in res.tokens.values())
    # serialized: the tiny pool caps concurrency, so decode batches are thin
    assert res.decode_steps >= 9


def test_request_too_large_rejected(smoke_model):
    cfg, model, p = smoke_model
    eng = Engine(model, p, method="quoka")
    prompts = [np.arange(64, dtype=np.int32) + 3]
    with pytest.raises(ValueError, match="never be admitted"):
        eng.serve(make_requests(prompts, 4), block_size=16, num_blocks=2)


def test_requests_can_be_reserved(smoke_model):
    """serve() resets request runtime state, so the same Request objects can
    be served twice (warmup-then-measure traces) with identical results."""
    cfg, model, p = smoke_model
    eng = Engine(model, p, method="quoka")
    rng = np.random.default_rng(11)
    reqs = make_requests([rng.integers(3, cfg.vocab, (32,)).astype(np.int32)],
                         4)
    r1 = eng.serve(reqs, block_size=16, max_decode_batch=2)
    r2 = eng.serve(reqs, block_size=16, max_decode_batch=2)
    np.testing.assert_array_equal(r1.tokens[0], r2.tokens[0])
    assert len(r2.tokens[0]) == 4


def test_ragged_tail_chunk_is_garbage_independent(smoke_model):
    """The serve path right-pads a partial tail chunk; whatever sits in the
    pad slots must not leak into the valid rows' output.  Pad KEYS were
    always masked (pos = -1), but pad QUERIES used to skew the chunk's
    mean-query/cosine statistics and thereby every row's KV selection."""
    import jax.numpy as jnp
    cfg, model, p = smoke_model
    chunk = cfg.quoka.chunk_size
    rng = np.random.default_rng(9)
    toks = rng.integers(3, cfg.vocab, (1, 2 * chunk)).astype(np.int32)
    tail = rng.integers(3, cfg.vocab, (1, 5)).astype(np.int32)
    outs = []
    for fill in (0, 7):                       # two different garbage fills
        cache = model.init_cache(1, 3 * chunk)
        for c0 in range(0, 2 * chunk, chunk):
            _, cache = model.prefill_chunk(
                p, {"tokens": jnp.asarray(toks[:, c0:c0 + chunk])},
                jnp.asarray(c0), cache, "quoka")
        buf = np.full((1, chunk), fill, np.int32)
        buf[:, :5] = tail
        last, _ = model.prefill_chunk(
            p, {"tokens": jnp.asarray(buf)}, jnp.asarray(2 * chunk), cache,
            "quoka", valid_len=jnp.asarray([5]))
        outs.append(np.asarray(last))
    np.testing.assert_array_equal(outs[0], outs[1])


def test_idle_wait_sleeps_until_next_arrival(smoke_model, monkeypatch):
    """A multi-second arrival gap must cost a handful of sleeps, not ~1000
    1 ms busy-spin wakeups per second — with identical step counts."""
    import time as time_mod

    import repro.serving.engine as eng_mod
    cfg, model, p = smoke_model
    eng = Engine(model, p, method="full")
    rng = np.random.default_rng(13)
    prompts = [rng.integers(3, cfg.vocab, (16,)).astype(np.int32)
               for _ in range(2)]
    kw = dict(block_size=16, max_decode_batch=2)
    eng.serve(make_requests(prompts, 3), **kw)        # compile warmup

    real_sleep = time_mod.sleep
    calls = []

    def counting_sleep(s):
        calls.append(s)
        real_sleep(min(s, 0.3))

    monkeypatch.setattr(eng_mod.time, "sleep", counting_sleep)
    res = eng.serve(make_requests(prompts, 3, arrivals=[0.0, 1.0]), **kw)
    # request 1 finishes well before request 2 arrives (compiled steps are
    # milliseconds).  Per request: one mixed prefill+first-decode step plus
    # one more decode step — the long idle sleep must not change that.
    assert res.steps == 4, res.steps
    assert res.prefill_steps == 2 and res.decode_steps == 4
    # the ~1 s idle gap: a few capped sleeps, not ~1000 1 ms wakeups
    assert 1 <= len(calls) <= 12, len(calls)
    assert all(len(v) == 3 for v in res.tokens.values())


def test_generate_reports_true_prompt_len(smoke_model):
    """prompt_len used to include pad_prompt's left padding, over-counting
    per-token TTFT normalisation for ragged prompts."""
    cfg, model, p = smoke_model
    eng = Engine(model, p, method="full")
    rng = np.random.default_rng(17)
    prompt = rng.integers(3, cfg.vocab, (1, 29)).astype(np.int32)
    r = eng.generate(eng.pad_prompt(prompt), 2)
    assert r.prompt_len == 29                 # not the padded 32
    r2 = eng.generate({"tokens": np.repeat(prompt[:, :16], 1, 0)}, 2)
    assert r2.prompt_len == 16                # no-pad batches unaffected


def test_eos_stops_early_and_frees(smoke_model):
    """EOS eviction: pick the greedy continuation's own first token as the
    EOS id, so the request stops after one decode step."""
    cfg, model, p = smoke_model
    eng = Engine(model, p, method="full")
    rng = np.random.default_rng(7)
    prompt = rng.integers(3, cfg.vocab, (16,)).astype(np.int32)
    ref = eng.generate({"tokens": prompt[None]}, 8).tokens[0]
    eos = int(ref[1])                       # second emitted token
    reqs = make_requests([prompt], 8, eos_id=eos)
    res = eng.serve(reqs, block_size=16, max_decode_batch=2)
    assert res.tokens[0].tolist() == ref[:2].tolist()
