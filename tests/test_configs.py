"""Config-system invariants for the assigned architecture pool."""
import dataclasses

import pytest

from repro.configs import ASSIGNED, get_config, list_configs

EXPECTED_PARAMS_B = {   # assignment name -> rough total params (1e9)
    "gemma3-27b": (25, 29),
    "granite-3-2b": (2, 3.3),
    "deepseek-v3-671b": (620, 720),
    "stablelm-3b": (2, 3.5),
    "internvl2-1b": (0.3, 1.2),
    "whisper-small": (0.1, 0.35),
    "rwkv6-1.6b": (0.9, 2.0),
    "olmoe-1b-7b": (6, 8),
    "h2o-danube-3-4b": (3, 4.6),
    "zamba2-7b": (5.5, 8.5),
}

EXACT_DIMS = {  # (n_layers, d_model, n_heads, n_kv, d_ff, vocab)
    "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
    "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
    "deepseek-v3-671b": (61, 7168, 128, 128, 18432, 129280),
    "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
    "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
    "whisper-small": (12, 768, 12, 12, 3072, 51865),
    "rwkv6-1.6b": (24, 2048, 32, 32, 7168, 65536),
    "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
    "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
    "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
}


def test_all_assigned_registered():
    known = set(list_configs())
    for a in ASSIGNED:
        assert a.replace(".", "-") in known


@pytest.mark.parametrize("arch", list(ASSIGNED))
def test_exact_assigned_dimensions(arch):
    cfg = get_config(arch)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == EXACT_DIMS[arch], (got, EXACT_DIMS[arch])
    assert cfg.source, "every assigned config must cite its source"


@pytest.mark.parametrize("arch", list(ASSIGNED))
def test_param_counts_in_band(arch):
    lo, hi = EXPECTED_PARAMS_B[arch]
    n = get_config(arch).param_count() / 1e9
    assert lo <= n <= hi, f"{arch}: {n:.2f}B outside [{lo}, {hi}]"


@pytest.mark.parametrize("arch", list(ASSIGNED))
def test_stacks_cover_all_layers(arch):
    cfg = get_config(arch)
    total = sum(len(p) * r for p, r in cfg.stacks())
    assert total == cfg.n_layers


@pytest.mark.parametrize("arch", list(ASSIGNED))
def test_smoke_variant_is_small(arch):
    s = get_config(arch).smoke()
    assert s.n_layers <= 2 and s.d_model <= 256 and s.vocab <= 512
    if s.moe:
        assert s.moe.n_experts <= 4
    # family-defining structure survives the reduction
    full_kinds = {k for p, _ in get_config(arch).stacks() for k in p}
    smoke_kinds = {k for p, _ in s.stacks() for k in p}
    assert smoke_kinds <= full_kinds
    assert len(smoke_kinds) >= min(2, len(full_kinds))


def test_moe_active_params():
    cfg = get_config("deepseek-v3-671b")
    assert cfg.active_param_count() < 0.08 * cfg.param_count()


def test_quoka_defaults_follow_paper():
    cfg = get_config("granite-3-2b")
    assert cfg.quoka.chunk_size == 128      # B_CP (paper §4)
    assert cfg.quoka.n_queries == 16        # N_Q  (paper §4)
    assert cfg.quoka.scoring == "cosine"
    assert cfg.quoka.query_agg == "max"
