"""Policy-driven serving control plane: FCFS parity through the policy
layer, preemption via block suspend/resume (token identity, replay
fallback), SLO scheduling, streaming serve, and the pack_prefill
tail-charging fix.  Randomized invariant sweeps guard the scheduler
mechanics: no request lost or duplicated across admissions, suspensions
and resumptions, and the pool's block accounting stays exact."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import build_model
from repro.serving import request as rq
from repro.serving.engine import Engine
from repro.serving.policy import FCFSPolicy, SLOPolicy, resolve_policy
from repro.serving.pool import PagedKVCache
from repro.serving.request import make_requests
from repro.serving.scheduler import Scheduler

pytestmark = pytest.mark.scheduling

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_config("granite-3-2b").smoke()
    model = build_model(cfg)
    return cfg, model, model.init(KEY)


def _drive(eng, state, reqs, *, hook=None, max_steps=500):
    """Manually run engine steps to drain ``reqs`` (arrivals ignored: all
    added up front).  ``hook(state, step_index)`` runs before each step —
    the test's handle for forcing suspensions mid-flight."""
    sched = state.sched
    for r in reqs:
        sched.add(r)
    steps = 0
    while sched.pending():
        if hook is not None:
            hook(state, steps)
        n_pf, n_dec = eng.step(state)
        assert n_pf or n_dec or not sched.pending(), "scheduler stall"
        steps += 1
        assert steps < max_steps, "drive did not drain"
    state.pool.check_invariants()
    return {r.rid: np.asarray(r.out, np.int32) for r in sched.done}


# ---------------------------------------------------------------------------
# FCFS parity through the policy layer
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("method", ["full", "quoka"])
def test_fcfs_policy_parity_including_prefix_hits(smoke_model, method):
    """Explicit FCFSPolicy == generate(), token for token — and a second
    pass over the warm pool (every request admitted via a prefix-cache hit)
    emits the same tokens again."""
    cfg, model, p = smoke_model
    eng = Engine(model, p, method=method)
    rng = np.random.default_rng(21)
    # lengths chosen so the hot pass hits under BOTH methods: quoka floors
    # a hit to the chunk grid AND caps at prompt_len - 1, so an exact
    # one-chunk prompt (16) would floor to a miss
    prompts = [rng.integers(3, cfg.vocab, (n,)).astype(np.int32)
               for n in (17, 48, 24)]
    refs = [eng.generate(eng.pad_prompt(pr[None]), 5).tokens[0]
            for pr in prompts]
    kw = dict(block_size=16, max_decode_batch=3, max_prefill_tokens=32)
    state = eng.make_serve_state(make_requests(prompts, 5),
                                 policy=FCFSPolicy(), **kw)
    cold = eng.serve(make_requests(prompts, 5), state=state)
    hot = eng.serve(make_requests(prompts, 5), state=state)
    assert cold.policy == "fcfs" and cold.preemptions == 0
    assert eng.stats["cache_hits"] == len(prompts)   # hot pass all hits
    for i, ref in enumerate(refs):
        np.testing.assert_array_equal(cold.tokens[i], ref)
        np.testing.assert_array_equal(hot.tokens[i], ref)


# ---------------------------------------------------------------------------
# suspend / resume
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("method", ["full", "quoka"])
@pytest.mark.parametrize("host_tier", [0, 32])
def test_suspend_resume_token_identity(smoke_model, method, host_tier):
    """Preempting a decoding request and resuming it (KV preserved — on the
    LRU list or demoted to the host tier) yields the exact tokens of an
    uninterrupted run, for dense and selection methods alike."""
    cfg, model, p = smoke_model
    eng = Engine(model, p, method=method)
    rng = np.random.default_rng(23)
    prompt = rng.integers(3, cfg.vocab, (40,)).astype(np.int32)
    kw = dict(block_size=16, max_decode_batch=2,
              policy=SLOPolicy(), host_tier_blocks=host_tier)
    state = eng.make_serve_state(make_requests([prompt], 8), **kw)
    ref = _drive(eng, state, make_requests([prompt], 8))[0]

    state = eng.make_serve_state(make_requests([prompt], 8), **kw)
    forced = []

    def force_suspend(st, step):
        r = st.sched.decoding[0] if st.sched.decoding else None
        if not forced and r is not None and len(r.out) >= 3:
            st.sched.suspend(r, st.now)
            forced.append(r)

    out = _drive(eng, state, make_requests([prompt], 8),
                 hook=force_suspend)[0]
    assert forced and forced[0].preemptions == 1
    assert state.sched.resumes == 1
    if host_tier:
        assert state.pool.demoted > 0 and state.pool.promoted > 0
    else:
        assert state.sched.resume_replays == 0   # KV intact on the LRU
    np.testing.assert_array_equal(out, ref)


def test_resume_replays_after_cache_loss(smoke_model):
    """If the suspended KV is evicted before resume, the scheduler replays
    the lost suffix through prefill chunks — exact for ``full`` (dense
    attention is chunking-invariant)."""
    cfg, model, p = smoke_model
    eng = Engine(model, p, method="full")
    rng = np.random.default_rng(29)
    prompt = rng.integers(3, cfg.vocab, (40,)).astype(np.int32)
    kw = dict(block_size=16, max_decode_batch=2, policy=SLOPolicy())
    state = eng.make_serve_state(make_requests([prompt], 8), **kw)
    ref = _drive(eng, state, make_requests([prompt], 8))[0]

    state = eng.make_serve_state(make_requests([prompt], 8), **kw)
    forced = []

    def suspend_then_trash(st, step):
        sched, pool = st.sched, st.pool
        if not forced and sched.decoding and len(sched.decoding[0].out) >= 3:
            sched.suspend(sched.decoding[0], st.now)
            # evict the parked KV: grab every free + evictable block, then
            # release — the registered suspend blocks are destroyed
            n = len(pool._free) + len(pool._lru)
            pool.alloc(10_000, n)
            pool.free(10_000)
            forced.append(True)

    out = _drive(eng, state, make_requests([prompt], 8),
                 hook=suspend_then_trash)[0]
    assert forced and state.sched.resumes == 1
    assert state.sched.resume_replays == 1       # cache loss -> replay
    np.testing.assert_array_equal(out, ref)


def test_randomized_suspend_resume_invariants(smoke_model):
    """Random preemptions across a multi-request trace: every request
    finishes exactly once with exactly max_new tokens, and the pool's
    refcount/free-list/registration invariants hold at every step."""
    cfg, model, p = smoke_model
    eng = Engine(model, p, method="quoka")
    rng = np.random.default_rng(31)
    prompts = [rng.integers(3, cfg.vocab, (int(n),)).astype(np.int32)
               for n in rng.integers(8, 48, 6)]
    reqs = make_requests(prompts, 5)
    state = eng.make_serve_state(reqs, block_size=16, max_decode_batch=3,
                                 policy=SLOPolicy())

    def random_suspend(st, step):
        sched = st.sched
        if sched.decoding and rng.random() < 0.3:
            victim = sched.decoding[int(rng.integers(len(sched.decoding)))]
            if victim.out:                       # decode_pos needs one token
                sched.suspend(victim, st.now)
        st.pool.check_invariants()

    out = _drive(eng, state, reqs, hook=random_suspend, max_steps=2000)
    assert sorted(out) == list(range(len(prompts)))      # none lost/duped
    assert all(len(v) == 5 for v in out.values())
    assert len(state.sched.done) == len(prompts)         # finished ONCE each
    assert not state.sched.waiting and not state.sched.suspended


def test_slo_policy_preempts_for_deadline(smoke_model):
    """One decode slot, a long background decode, then an interactive
    deadline-carrying arrival: SLOPolicy suspends the background request to
    admit the interactive one; FCFS on the same trace never preempts."""
    cfg, model, p = smoke_model
    rng = np.random.default_rng(37)
    bg = rng.integers(3, cfg.vocab, (32,)).astype(np.int32)
    inter = rng.integers(3, cfg.vocab, (16,)).astype(np.int32)

    def reqs():
        return make_requests(
            [bg, inter], [64, 2], arrivals=[0.0, 0.02],
            tenants=["background", "interactive"],
            ttft_deadlines=[None, 0.01])

    eng = Engine(model, p, method="full")
    kw = dict(block_size=16, max_decode_batch=1, max_prefill_tokens=32)
    fcfs = eng.serve(reqs(), policy="fcfs", **kw)
    eng.serve(reqs(), policy="slo", **kw)            # compile warmup (slo
    slo = eng.serve(reqs(), policy="slo", **kw)      # geometry is wider)
    assert fcfs.preemptions == 0
    assert slo.preemptions >= 1 and slo.resumes >= 1
    assert slo.policy == "slo"
    # every request still completes in full on both arms
    for res in (fcfs, slo):
        assert len(res.tokens[0]) == 64 and len(res.tokens[1]) == 2
    # the interactive request stopped waiting behind the background decode
    assert slo.ttft_s[1] < fcfs.ttft_s[1]


# ---------------------------------------------------------------------------
# pack_prefill tail charging (satellite bugfix)
# ---------------------------------------------------------------------------
def test_pack_prefill_charges_real_tail_length(smoke_model):
    """Two short tail chunks must pack into ONE step when the row geometry
    allows it: each charges its real (grid-rounded) length, not a whole
    padded chunk of the token budget."""
    cfg, model, p = smoke_model
    pool = PagedKVCache(model, num_blocks=8, block_size=16)
    reqs = make_requests([np.arange(5, dtype=np.int32) + 3,
                          np.arange(6, dtype=np.int32) + 3], 2)
    sched = Scheduler(pool, chunk_size=16, max_prefill_tokens=16,
                      max_decode_batch=2, max_prefill_rows=2)
    for r in reqs:
        sched.add(r)
    sched.admit()
    rows = sched.pack_prefill()
    assert len(rows) == 2                      # both tails in one step
    assert [vl for _, _, _, vl in rows] == [5, 6]

    # control: the default row geometry (budget // chunk == 1 row) keeps
    # the old one-chunk-per-step packing
    pool2 = PagedKVCache(model, num_blocks=8, block_size=16)
    sched2 = Scheduler(pool2, chunk_size=16, max_prefill_tokens=16,
                       max_decode_batch=2)
    for r in make_requests([np.arange(5, dtype=np.int32) + 3,
                            np.arange(6, dtype=np.int32) + 3], 2):
        sched2.add(r)
    sched2.admit()
    assert len(sched2.pack_prefill()) == 1


def test_tail_packing_end_to_end(smoke_model):
    """Engine-level: with ``max_prefill_rows=2`` and a one-chunk token
    budget, two sub-chunk prompts prefill in a single engine step — and
    still match generate() token for token."""
    cfg, model, p = smoke_model
    chunk = cfg.quoka.chunk_size
    eng = Engine(model, p, method="full")
    rng = np.random.default_rng(41)
    prompts = [rng.integers(3, cfg.vocab, (chunk // 2 - 1,)).astype(np.int32),
               rng.integers(3, cfg.vocab, (chunk // 2,)).astype(np.int32)]
    refs = [eng.generate(eng.pad_prompt(pr[None]), 4).tokens[0]
            for pr in prompts]
    res = eng.serve(make_requests(prompts, 4), block_size=16,
                    max_decode_batch=2, max_prefill_tokens=chunk,
                    max_prefill_rows=2)
    assert res.prefill_steps == 1, res.prefill_steps
    for i, ref in enumerate(refs):
        np.testing.assert_array_equal(res.tokens[i], ref)


# ---------------------------------------------------------------------------
# heterogeneous make_requests (satellite)
# ---------------------------------------------------------------------------
def test_make_requests_heterogeneous_fields(smoke_model):
    cfg, model, p = smoke_model
    rng = np.random.default_rng(43)
    prompts = [rng.integers(3, cfg.vocab, (16,)).astype(np.int32)
               for _ in range(2)]
    reqs = make_requests(prompts, [2, 5], eos_id=[None, 7],
                         tenants=["a", "b"], priorities=[0, 3],
                         ttft_deadlines=[None, 1.5])
    assert [r.max_new for r in reqs] == [2, 5]
    assert [r.eos_id for r in reqs] == [None, 7]
    assert [r.tenant for r in reqs] == ["a", "b"]
    assert [r.priority for r in reqs] == [0, 3]
    assert [r.ttft_deadline_s for r in reqs] == [None, 1.5]
    with pytest.raises(ValueError, match="max_new"):
        make_requests(prompts, [2])
    # per-request max_new is honoured end to end
    eng = Engine(model, p, method="full")
    res = eng.serve(make_requests(prompts, [2, 5]), block_size=16,
                    max_decode_batch=2)
    assert len(res.tokens[0]) == 2 and len(res.tokens[1]) == 5


# ---------------------------------------------------------------------------
# deadlines + per-tenant telemetry
# ---------------------------------------------------------------------------
def test_deadline_miss_counters_and_tenant_views(smoke_model):
    from repro.obs import Registry
    cfg, model, p = smoke_model
    reg = Registry()
    eng = Engine(model, p, method="full", registry=reg)
    rng = np.random.default_rng(47)
    prompts = [rng.integers(3, cfg.vocab, (16,)).astype(np.int32)
               for _ in range(2)]
    res = eng.serve(
        make_requests(prompts, 2, tenants=["t0", "t1"],
                      ttft_deadlines=[0.0, 1e9]),     # t0 cannot make 0 s
        block_size=16, max_decode_batch=2)
    assert res.deadline_misses == 1
    assert reg.counters["serve/deadline_miss"].value == 1
    assert reg.counters["tenant/t0/deadline_miss"].value == 1
    assert reg.counters["tenant/t1/deadline_met"].value == 1
    t0 = reg.view("tenant/t0")
    assert "deadline_miss" in t0 and "ttft_s" not in reg.counters


# ---------------------------------------------------------------------------
# streaming serve
# ---------------------------------------------------------------------------
def test_serve_stream_yields_per_step(smoke_model):
    """serve_stream yields every (rid, token) pair as it is emitted; the
    drained stream's return value is the full ServeResult and matches what
    the yielded events reconstruct."""
    cfg, model, p = smoke_model
    eng = Engine(model, p, method="full")
    rng = np.random.default_rng(53)
    prompts = [rng.integers(3, cfg.vocab, (n,)).astype(np.int32)
               for n in (16, 24)]
    kw = dict(block_size=16, max_decode_batch=2)
    eng.serve(make_requests(prompts, 4), **kw)          # compile warmup
    stream = eng.serve_stream(make_requests(prompts, 4), **kw)
    events, res = [], None
    while True:
        try:
            events.append(next(stream))
        except StopIteration as stop:
            res = stop.value
            break
    assert res is not None and res.generated == len(events) == 8
    by_rid = {}
    for rid, tok in events:
        by_rid.setdefault(rid, []).append(tok)
    for rid, toks in by_rid.items():
        np.testing.assert_array_equal(np.asarray(toks, np.int32),
                                      res.tokens[rid])


def test_serve_is_a_stream_drain(smoke_model):
    """serve() and a manual serve_stream drain produce identical tokens
    (greedy) for the same trace."""
    cfg, model, p = smoke_model
    eng = Engine(model, p, method="full")
    rng = np.random.default_rng(59)
    prompts = [rng.integers(3, cfg.vocab, (16,)).astype(np.int32)]
    kw = dict(block_size=16, max_decode_batch=1)
    r1 = eng.serve(make_requests(prompts, 4), **kw)
    r2_stream = eng.serve_stream(make_requests(prompts, 4), **kw)
    toks = [t for _, t in r2_stream]
    np.testing.assert_array_equal(np.asarray(toks, np.int32), r1.tokens[0])


# ---------------------------------------------------------------------------
# policy resolution
# ---------------------------------------------------------------------------
def test_resolve_policy():
    assert isinstance(resolve_policy(None), FCFSPolicy)
    assert resolve_policy("slo").name == "slo"
    pol = SLOPolicy(weights={"a": 2.0})
    assert resolve_policy(pol) is pol
    with pytest.raises(ValueError, match="unknown scheduling policy"):
        resolve_policy("nope")
    with pytest.raises(TypeError):
        resolve_policy(7)


def test_slo_policy_ordering_and_victims():
    mk = lambda rid, arr, dl, pr=0, tenant="t": rq.Request(
        rid=rid, tokens=np.zeros(4, np.int32), max_new=4, arrival_s=arr,
        ttft_deadline_s=dl, priority=pr, tenant=tenant)
    pol = SLOPolicy(risk_frac=0.0)
    a = mk(0, 0.0, None)          # no deadline -> least urgent
    b = mk(1, 0.1, 0.5)           # deadline 0.6
    c = mk(2, 0.0, 0.3)           # deadline 0.3 -> most urgent
    assert [r.rid for r in pol.order_admission([], [a, b, c], 1.0)] \
        == [2, 1, 0]
    # victims must hold a STRICTLY later deadline than the blocked request
    d1, d2 = mk(3, 0.0, None), mk(4, 0.0, 0.3)
    d1.status = d2.status = rq.DECODE
    d1.out, d2.out = [1, 2, 3], [1]
    assert pol.pick_victim(c, [d1, d2], now=1.0) is d1    # equal dl excluded
    assert pol.pick_victim(c, [d2], now=1.0) is None
    assert pol.pick_victim(a, [d1], now=1.0) is None      # no deadline, no risk
    # fairness: the most-served tenant is sacrificed first
    pol.note_work(mk(5, 0, None, tenant="fat"), 1000)
    f1 = mk(6, 0.0, None, tenant="fat")
    f2 = mk(7, 0.0, None, tenant="thin")
    f1.status = f2.status = rq.DECODE
    f1.out = f2.out = [1]
    assert pol.pick_victim(c, [f2, f1], now=1.0) is f1
