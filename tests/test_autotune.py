"""Autotuner round-trip, lookup determinism and tuning-table lint
(kernels/autotune.py)."""
import json

import pytest

from repro.kernels import autotune


@pytest.fixture
def scratch_table(tmp_path, monkeypatch):
    """Point the active table at an empty scratch file and drop the
    in-process cache on both sides of the test."""
    path = tmp_path / "tuning.json"
    monkeypatch.setenv("REPRO_TUNING", str(path))
    autotune.invalidate_cache()
    yield str(path)
    autotune.invalidate_cache()


def test_lookup_defaults_deterministic(scratch_table):
    a = autotune.lookup("flash_attention", t=333, d=48, n_kv=3, budget=17)
    b = autotune.lookup("flash_attention", t=333, d=48, n_kv=3, budget=17)
    assert a == b == autotune.default_params("flash_attention", {})


def test_roundtrip_miss_searches_then_hits(scratch_table):
    key = dict(t=256, d=32, n_kv=2, budget=64, g=16, backend="cpu")
    calls = []

    def measure(params):
        calls.append(dict(params))
        # prefer a non-default geometry so the hit is distinguishable
        return 1e-3 if params["block_q"] == 64 else 2e-3

    s0, h0 = autotune.SEARCHES, autotune.HITS
    won = autotune.autotune("selected_attention", measure, **key)
    assert autotune.SEARCHES == s0 + 1
    assert len(calls) > 1                       # the search really ran
    assert won["block_q"] == 64

    # persisted: the scratch table now holds exactly this entry
    with open(scratch_table) as f:
        doc = json.load(f)
    assert doc["schema_version"] == autotune.SCHEMA_VERSION
    assert len(doc["entries"]) == 1
    assert doc["entries"][0]["key"]["t"] == 256
    assert autotune.lint(scratch_table) == []

    # second invocation, same key: table hit, NO re-search, no measuring
    calls.clear()
    again = autotune.autotune("selected_attention", measure, **key)
    assert again == won
    assert autotune.SEARCHES == s0 + 1          # unchanged
    assert autotune.HITS == h0 + 1
    assert calls == []

    # a cold process (cache dropped) re-reads the persisted file as a hit
    autotune.invalidate_cache()
    assert autotune.lookup("selected_attention", **key) == won


def test_autotune_survives_infeasible_candidates(scratch_table):
    def measure(params):
        if params["block_k"] != 128:
            raise ValueError("infeasible geometry")
        return 1e-3

    won = autotune.autotune("flash_attention", measure, t=128, d=32, n_kv=2,
                            backend="cpu")
    assert won["block_k"] == 128


def test_autotune_rejects_unknown_kernel(scratch_table):
    with pytest.raises(ValueError, match="unknown kernel"):
        autotune.autotune("nope", lambda p: 1.0, t=8, d=8, n_kv=1)


def test_committed_table_lints_clean():
    assert autotune.lint(autotune.DEFAULT_TABLE) == []


def test_lint_catches_bad_entries(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({
        "schema_version": autotune.SCHEMA_VERSION,
        "entries": [
            {"kernel": "flash_attention",
             "key": {"backend": "cpu", "t": 128, "d": 64, "n_kv": 2,
                     "budget": 0, "g": 1},
             "params": {"block_q": 0, "block_k": 128, "num_stages": 2,
                        "dimension_semantics": ["parallel", "bogus"]}},
            {"kernel": "not_a_kernel", "key": {}, "params": {}},
        ]}))
    errs = autotune.lint(str(bad))
    assert any("block_q" in e for e in errs)
    assert any("dimension_semantics" in e for e in errs)
    assert any("unknown kernel" in e for e in errs)


def test_lint_flags_duplicate_keys(tmp_path):
    entry = {"kernel": "flash_attention",
             "key": {"backend": "cpu", "t": 128, "d": 64, "n_kv": 2,
                     "budget": 0, "g": 1},
             "params": {"block_q": 128, "block_k": 128, "num_stages": 2,
                        "dimension_semantics": ["parallel", "arbitrary"]}}
    dup = tmp_path / "dup.json"
    dup.write_text(json.dumps({"schema_version": autotune.SCHEMA_VERSION,
                               "entries": [entry, entry]}))
    assert any("duplicate" in e for e in autotune.lint(str(dup)))


def test_flash_attention_consults_table(scratch_table):
    """flash_attention_bhtd with unpinned block sizes resolves through the
    active table: a committed entry changes the traced geometry, defaults
    keep the pre-autotuner behaviour."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ref
    from repro.kernels.flash_attention import flash_attention_bhtd

    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 2, 64, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 96, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 96, 16))

    out_default = flash_attention_bhtd(q, k, v, causal=True)
    entry = {"kernel": "flash_attention",
             "key": {"backend": "cpu", "t": 96, "d": 16, "n_kv": 2,
                     "budget": 0, "g": 1},
             "params": {"block_q": 32, "block_k": 32, "num_stages": 2,
                        "dimension_semantics": ["parallel", "parallel",
                                                "parallel", "arbitrary"]}}
    with open(scratch_table, "w") as f:
        json.dump({"schema_version": autotune.SCHEMA_VERSION,
                   "entries": [entry]}, f)
    autotune.invalidate_cache()
    out_tuned = flash_attention_bhtd(q, k, v, causal=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    for out in (out_default, out_tuned):
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)
