"""Per-architecture smoke tests (deliverable f): every assigned arch
instantiates a REDUCED variant of the same family (<=2 layers, d_model<=256,
<=4 experts) and runs one forward + one train step on CPU, asserting output
shapes and absence of NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models.model import build_model
from repro.training import loop as train_loop
from repro.training import optimizer as opt

KEY = jax.random.PRNGKey(0)
ALL = list(ASSIGNED) + ["llama3-2-3b", "qwen3-4b"]


def _batch(cfg, b=2, t=64):
    batch = {"tokens": jax.random.randint(KEY, (b, t), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            KEY, (b, cfg.frontend.n_tokens, cfg.frontend.d_in))
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            KEY, (b, cfg.encoder.n_ctx, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ALL)
def test_forward_shapes_and_no_nans(arch):
    cfg = get_config(arch).smoke()
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    logits, aux = model.train_logits(params, batch)
    t_total = 64 + (cfg.frontend.n_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (2, t_total, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ALL)
def test_one_train_step(arch):
    cfg = get_config(arch).smoke()
    model = build_model(cfg)
    state = train_loop.init_state(model, KEY)
    step = train_loop.make_train_step(
        model, opt.OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=10))
    state2, metrics = step(state, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(state.params),
                        jax.tree.leaves(state2.params)))
    assert changed


@pytest.mark.parametrize("arch", ["granite-3-2b", "gemma3-27b", "olmoe-1b-7b",
                                  "deepseek-v3-671b", "zamba2-7b",
                                  "rwkv6-1.6b", "whisper-small",
                                  "internvl2-1b"])
def test_prefill_and_decode(arch):
    """Chunked prefill with QUOKA + one decode step, no NaNs."""
    cfg = get_config(arch).smoke()
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    extra = cfg.frontend.n_tokens if cfg.family == "vlm" else 0
    cache = model.init_cache(2, 64 + extra + 4)
    logits, cache = model.prefill(params, batch, cache, "quoka")
    assert logits.shape == (2, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())
    lg2, cache = model.decode_step(params, jnp.zeros(2, jnp.int32),
                                   64 + extra, cache, "quoka")
    assert lg2.shape == (2, cfg.vocab)
    assert not bool(jnp.isnan(lg2).any())
