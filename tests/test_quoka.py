"""Unit tests for the paper's Algorithm 1 (core/quoka.py scoring +
core/plan.py select/materialize)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import QuokaConfig
from repro.core import plan as plan_mod
from repro.core.attention import NEG_INF
from repro.core.quoka import Selected, quoka_scores, subselect_queries
from repro.models.layers import cosine_sim, l2_normalize

KEY = jax.random.PRNGKey(0)


def test_subselect_picks_most_dissimilar():
    """The kept queries must be exactly the N_Q lowest-CosSim(M_Q, q)."""
    b, t, h, d = 1, 32, 1, 16
    q = jax.random.normal(KEY, (b, t, h, d))
    n_q = 5
    kept = subselect_queries(q, n_q)
    mq = q.mean(axis=1, keepdims=True)
    s = cosine_sim(q, mq)                       # (b, t, h)
    order = np.argsort(np.asarray(s[0, :, 0]))  # ascending cosine
    want = set(order[:n_q].tolist())
    got_rows = np.asarray(kept[0, :, 0, :])
    all_rows = np.asarray(q[0, :, 0, :])
    got = {int(np.argmin(np.linalg.norm(all_rows - r, axis=1)))
           for r in got_rows}
    assert got == want


def test_subselect_noop_when_small():
    q = jax.random.normal(KEY, (2, 8, 4, 16))
    assert subselect_queries(q, 16) is q


def test_preaggregation_equals_post_mean():
    """Paper §3.3: averaging normalised queries inside a KV group BEFORE the
    matmul equals averaging per-head cosine scores (linearity)."""
    b, nq, h, n_kv, d, t = 2, 4, 8, 2, 16, 64
    q = jax.random.normal(KEY, (b, nq, h, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, t, n_kv, d))
    valid = jnp.ones((b, t), bool)
    cfg = QuokaConfig(query_agg="max")
    got = quoka_scores(q, k, valid, cfg)
    # reference: per attention head cosine, then mean over the group
    qn = l2_normalize(q.astype(jnp.float32))
    kn = l2_normalize(k.astype(jnp.float32))
    s = jnp.einsum("bnhd,bthd->bhnt", qn,
                   jnp.repeat(kn, h // n_kv, axis=2))
    s_group = s.reshape(b, n_kv, h // n_kv, nq, t).mean(axis=2)
    want = s_group.max(axis=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_scores_masked_invalid():
    b, nq, h, n_kv, d, t = 1, 2, 2, 1, 8, 16
    q = jax.random.normal(KEY, (b, nq, h, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 2), (b, t, n_kv, d))
    valid = jnp.arange(t)[None, :] < 10
    s = quoka_scores(q, k, valid, QuokaConfig())
    assert bool((s[:, :, 10:] <= NEG_INF / 2).all())
    assert bool((s[:, :, :10] > NEG_INF / 2).all())


def test_select_topk_budget_and_positions():
    b, n_kv, t, d = 2, 2, 64, 8
    k = jax.random.normal(KEY, (b, t, n_kv, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 1), (b, t, n_kv, d))
    key_pos = jnp.arange(t)[None].repeat(b, 0)
    scores = jax.random.normal(jax.random.fold_in(KEY, 2),
                               (b, n_kv, t)).astype(jnp.float32)
    cfg = QuokaConfig(keep_first=0)
    pln = plan_mod.plan_from_scores(scores, key_pos, cfg, budget=16)
    sel = plan_mod.materialize(pln, k, v, key_pos, jnp.asarray(t), cfg)
    assert sel.k.shape == (b, 16, n_kv, d)
    assert sel.pos.shape == (b, n_kv, 16)
    # gathered values must equal source rows at the selected slots
    for bi in range(b):
        for hi in range(n_kv):
            for j in range(16):
                slot = int(sel.idx[bi, hi, j])
                np.testing.assert_allclose(
                    np.asarray(sel.k[bi, j, hi]), np.asarray(k[bi, slot, hi]))


def test_select_topk_respects_keep_first():
    """Sink protection: the first keep_first positions are always selected."""
    b, n_kv, t, d = 1, 1, 64, 8
    k = jax.random.normal(KEY, (b, t, n_kv, d))
    key_pos = jnp.arange(t)[None]
    scores = jnp.where(jnp.arange(t)[None, None, :] < 4, -5.0, 1.0)
    scores = scores.astype(jnp.float32)
    cfg = QuokaConfig(keep_first=4)
    pln = plan_mod.plan_from_scores(scores, key_pos, cfg, budget=8)
    sel = plan_mod.materialize(pln, k, k, key_pos, jnp.asarray(t), cfg)
    got = set(np.asarray(sel.pos[0, 0]).tolist())
    assert {0, 1, 2, 3} <= got


def test_select_fewer_valid_than_budget():
    b, n_kv, t, d = 1, 1, 32, 4
    k = jax.random.normal(KEY, (b, t, n_kv, d))
    key_pos = jnp.arange(t)[None]
    q = jax.random.normal(KEY, (b, 8, 2, d))
    sel = plan_mod.select("quoka", q, k, k, key_pos, jnp.asarray(5),
                          QuokaConfig(budget=16, n_queries=4, keep_first=0))
    valid = np.asarray(sel.pos[0, 0]) >= 0
    assert valid.sum() == 5                      # only 5 selectable
    assert (np.asarray(sel.pos[0, 0])[valid] < 5).all()


def test_ragged_tail_queries_do_not_skew_selection():
    """Regression: a chunk whose tail rows are padding garbage (pos = -1
    under continuous batching) must select exactly what the truncated
    valid-only chunk selects — garbage queries used to enter the mean-query
    and the cosine top-k and skew every head's scores."""
    cfg = QuokaConfig(budget=8, n_queries=4, keep_first=0)
    b, t, h, n_kv, d, cap = 1, 16, 4, 2, 16, 48
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, cap, n_kv, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, cap, n_kv, d))
    key_pos = jnp.arange(cap, dtype=jnp.int32)[None]
    vlen = 5
    q = jax.random.normal(KEY, (b, vlen, h, d))
    garbage = 50.0 * jax.random.normal(jax.random.fold_in(KEY, 9),
                                       (b, t - vlen, h, d))
    q_full = jnp.concatenate([q, garbage], axis=1)
    q_valid = (jnp.arange(t) < vlen)[None]

    sel = lambda qq, **kw: plan_mod.select("quoka", qq, k, v, key_pos,
                                           jnp.asarray(32), cfg, **kw)
    ref = sel(q)
    got = sel(q_full, q_valid=q_valid)
    np.testing.assert_array_equal(np.asarray(got.idx), np.asarray(ref.idx))
    np.testing.assert_array_equal(np.asarray(got.pos), np.asarray(ref.pos))
    np.testing.assert_allclose(np.asarray(got.k), np.asarray(ref.k))
    # ...and fewer valid queries than N_Q degrades to harmless duplicates
    # (t <= n_queries early-return keeps sanitized rows only)
    got2 = sel(q_full[:, :6], q_valid=q_valid[:, :6])
    ref2 = sel(q_full[:, :5])
    np.testing.assert_array_equal(np.asarray(got2.idx), np.asarray(ref2.idx))


def test_theorem1_bound():
    """Numeric check of Theorem 1: for CosSim(k,q*)=beta>0 and
    CosSim(M_Q,k)=alpha<0, CosSim(M_Q,q*) <= 1 + a*b - a^2/2 - b^2/2."""
    rng = np.random.default_rng(0)
    for _ in range(200):
        d = 16
        k = rng.normal(size=d)
        q = rng.normal(size=d)
        mq = rng.normal(size=d)
        cs = lambda a, b: float(np.dot(a, b) /
                                (np.linalg.norm(a) * np.linalg.norm(b)))
        beta, alpha = cs(k, q), cs(mq, k)
        if beta <= 0 or alpha >= 0:
            continue
        bound = 1 + alpha * beta - 0.5 * alpha ** 2 - 0.5 * beta ** 2
        assert cs(mq, q) <= bound + 1e-9


def test_scoring_scale_invariance():
    """Cosine scoring must be invariant to per-vector scaling (the paper's
    argument for cosine over dot)."""
    b, nq, h, n_kv, d, t = 1, 4, 4, 2, 8, 32
    q = jax.random.normal(KEY, (b, nq, h, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 3), (b, t, n_kv, d))
    valid = jnp.ones((b, t), bool)
    cfg = QuokaConfig(scoring="cosine")
    s1 = quoka_scores(q, k, valid, cfg)
    s2 = quoka_scores(q * 7.3, k * 0.11, valid, cfg)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-5)
    cfg_dot = QuokaConfig(scoring="dot")
    s3 = quoka_scores(q, k, valid, cfg_dot)
    assert not np.allclose(np.asarray(s1), np.asarray(s3), atol=1e-3)
