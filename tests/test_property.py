"""Hypothesis property tests on the system's invariants.

``hypothesis`` is an optional test extra: when it is not installed the whole
module degrades to a skip so tier-1 collection stays green.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.configs.base import QuokaConfig
from repro.core.attention import (attention_with_positions, blocked_attention,
                                  dense_attention, position_mask)
from repro.core import plan as plan_mod
from repro.core.quoka import subselect_queries

SETTINGS = dict(max_examples=20, deadline=None, derandomize=True,
                suppress_health_check=[hypothesis.HealthCheck.too_slow])


def _arr(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


@given(seed=st.integers(0, 2**16), t=st.integers(8, 48),
       h=st.sampled_from([2, 4]), nkv=st.sampled_from([1, 2]),
       budget=st.integers(2, 64))
@settings(**SETTINGS)
def test_selection_only_picks_valid_prior_slots(seed, t, h, nkv, budget):
    """Selected positions are always in [0, chunk_start) or -1 padding."""
    d = 8
    q = _arr(seed, (1, 8, h, d))
    k = _arr(seed + 1, (1, t, nkv, d))
    key_pos = jnp.arange(t)[None]
    start = max(1, t // 2)
    sel = plan_mod.select("quoka", q, k, k, key_pos, jnp.asarray(start),
                          QuokaConfig(budget=budget, n_queries=4,
                                      keep_first=2))
    pos = np.asarray(sel.pos)
    assert ((pos == -1) | ((pos >= 0) & (pos < start))).all()
    n_valid = (pos[0, 0] >= 0).sum()
    assert n_valid == min(budget, t, start)


@given(seed=st.integers(0, 2**16), scale=st.floats(0.1, 10.0))
@settings(**SETTINGS)
def test_quoka_selection_scale_invariant(seed, scale):
    """Cosine scoring ⇒ the selected index SET is invariant to rescaling."""
    q = _arr(seed, (1, 16, 4, 8))
    k = _arr(seed + 1, (1, 64, 2, 8))
    key_pos = jnp.arange(64)[None]
    cfg = QuokaConfig(budget=16, n_queries=8, keep_first=0)
    s1 = plan_mod.select("quoka", q, k, k, key_pos, jnp.asarray(60), cfg)
    s2 = plan_mod.select("quoka", q * scale, k * scale, k * scale, key_pos,
                         jnp.asarray(60), cfg)
    a = np.sort(np.asarray(s1.idx), axis=-1)
    b = np.sort(np.asarray(s2.idx), axis=-1)
    assert (a == b).all()


@given(seed=st.integers(0, 2**16), t=st.integers(4, 40),
       nq=st.integers(1, 24))
@settings(**SETTINGS)
def test_subselect_queries_shape_and_membership(seed, t, nq):
    q = _arr(seed, (2, t, 2, 8))
    out = subselect_queries(q, nq)
    assert out.shape == (2, min(t, nq) if t > nq else t, 2, 8)
    # each kept row must be an actual input row (per batch/head)
    qa = np.asarray(q[0, :, 0])
    for row in np.asarray(out[0, :, 0]):
        assert np.isclose(np.abs(qa - row).sum(axis=1).min(), 0, atol=1e-6)


@given(seed=st.integers(0, 2**16), tq=st.integers(1, 24),
       tk=st.integers(1, 80), causal=st.booleans())
@settings(**SETTINGS)
def test_attention_rows_are_convex_combinations(seed, tq, tk, causal):
    """Attention outputs lie in the convex hull of V (max |out| <= max |v|)."""
    q = _arr(seed, (1, tq, 2, 8))
    k = _arr(seed + 1, (1, tk, 2, 8))
    v = _arr(seed + 2, (1, tk, 2, 8))
    qp = jnp.arange(tk, tk + tq)[None]
    kp = jnp.arange(tk)[None]
    out = attention_with_positions(q, k, v, qp, kp, causal=causal)
    assert float(jnp.abs(out).max()) <= float(jnp.abs(v).max()) + 1e-4


@given(seed=st.integers(0, 2**16), tq=st.integers(1, 16),
       tk=st.integers(2, 100), window=st.one_of(st.none(),
                                                st.integers(2, 32)))
@settings(**SETTINGS)
def test_blocked_equals_dense(seed, tq, tk, window):
    q = _arr(seed, (1, tq, 4, 8))
    k = _arr(seed + 1, (1, tk, 2, 8))
    v = _arr(seed + 2, (1, tk, 2, 8))
    qp = jnp.arange(tk - tq, tk)[None] if tk >= tq else jnp.arange(tq)[None]
    kp = jnp.arange(tk)[None]
    mask = position_mask(qp, kp, causal=True, window=window)
    want = dense_attention(q, k, v, mask)
    got = blocked_attention(q, k, v, qp, kp, causal=True, window=window,
                            block_k=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-3)


@given(seed=st.integers(0, 2**16),
       method=st.sampled_from(["quoka", "sparq", "loki", "keydiff",
                               "snapkv", "sample_attention"]))
@settings(**SETTINGS)
def test_all_methods_select_within_budget(seed, method):
    q = _arr(seed, (1, 16, 4, 8))
    k = _arr(seed + 1, (1, 64, 2, 8))
    key_pos = jnp.arange(64)[None]
    cfg = QuokaConfig(budget=12, n_queries=4, keep_first=2)
    sel = plan_mod.select(method, q, k, k, key_pos, jnp.asarray(48), cfg)
    pos = np.asarray(sel.pos)
    assert pos.shape[-1] == 12
    assert ((pos == -1) | ((pos >= 0) & (pos < 48))).all()
