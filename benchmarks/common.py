"""Shared benchmark utilities: wall-clock timing of jitted fns, CSV rows,
and a structured JSON sink (benchmarks/out/<name>.json) so backend-vs-backend
trajectories can be tracked across runs."""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List

import jax

ROWS: List[str] = []
RECORDS: List[Dict] = []

# interpreted Pallas kernels execute the kernel body per grid cell in
# Python — they validate the dispatch path, not speed — so backend-axis
# benchmarks cap them at this length
INTERPRET_MAX_T = 1024


def backend_axis():
    """Backends every backend-axis benchmark sweeps: xla always; the
    compiled kernel on TPU, the interpreted kernel elsewhere."""
    from repro.kernels import ops as kops
    auto = kops.resolve_backend()
    return ("xla", "pallas") if auto == "pallas" else ("xla",
                                                       "pallas_interpret")


def emit(name: str, us_per_call: float, derived: str = "", **fields):
    """Record one benchmark point.  ``fields`` (e.g. backend=, method=,
    seq_len=) go into the JSON record; the CSV row keeps the legacy
    ``name,us,derived`` shape."""
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    RECORDS.append({"name": name, "us_per_call": us_per_call,
                    "derived": derived, **fields})
    print(row, flush=True)


def json_mark() -> int:
    """Snapshot the record count; pass to write_json to dump only the
    records a single benchmark produced."""
    return len(RECORDS)


def write_json(bench: str, start: int = 0,
               out_dir: str = os.path.join(os.path.dirname(__file__), "out")):
    """Dump RECORDS[start:] to benchmarks/out/<bench>.json."""
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{bench}.json")
    with open(path, "w") as f:
        json.dump(RECORDS[start:], f, indent=2)
    print(f"# wrote {len(RECORDS) - start} records -> {path}", flush=True)
    return path


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-clock microseconds per call of a jitted fn."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def header(title: str):
    print(f"\n# --- {title} ---", flush=True)
