"""Paper Table 4 + Appendix C: runtime & memory complexity of the scoring
pass per method — analytic terms evaluated at llama3.2-3B dims, plus
MEASURED scoring wall-clock to confirm the pre-aggregation factor n_q/n_kv.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import emit, header, time_fn
from repro.configs.base import QuokaConfig
from repro.core import selection as sel_mod
from repro.core.quoka import quoka_scores, subselect_queries

# llama3.2-3B dims (paper's primary model)
D, NQH, NKV, BCP, NQ, DL = 128, 24, 8, 128, 16, 64


def analytic(t: int):
    """Scoring-pass term counts from paper Table 4 (per layer, b=1)."""
    return {
        "quoka": ("runtime", BCP + (NQ * (1 + D * NKV)) * t,
                  "memory", NKV * NQ * t),
        "sample_attention": ("runtime",
                             (D * NQH + NQH / NKV + NKV) * NQ * t,
                             "memory", NQH * NQ * t),
        "sparq": ("runtime", BCP * t * DL * NQH, "memory", NQH * BCP * t),
        "loki": ("runtime", DL * NQH * (BCP * t + D * (BCP + t)),
                 "memory", NQH * BCP * t),
        "less_is_more": ("runtime", D * NQH * BCP * t / 28,
                         "memory", NQH * BCP * t / 28),
    }


def run():
    header("complexity (Table 4)")
    t = 8192
    for m, (_, rt, __, mem) in analytic(t).items():
        emit(f"complexity_analytic/T{t}/{m}", 0.0,
             f"runtime_terms={rt:.3e};memory_terms={mem:.3e}")

    # measured: scoring-only wall clock, full-head vs pre-aggregated
    key = jax.random.PRNGKey(0)
    cfg = QuokaConfig(chunk_size=BCP, budget=1024, n_queries=NQ)
    for t in (2048, 8192):
        q = jax.random.normal(key, (1, BCP, NQH, D))
        k = jax.random.normal(jax.random.fold_in(key, 1), (1, t, NKV, D))
        valid = jnp.ones((1, t), bool)

        def quoka_fn(q, k, valid):
            return quoka_scores(subselect_queries(q, NQ), k, valid, cfg)

        us_q = time_fn(jax.jit(quoka_fn), q, k, valid)
        us_s = time_fn(jax.jit(functools.partial(
            sel_mod.sample_attention_scores, cfg=cfg)), q, k, valid)
        emit(f"complexity_measured/T{t}/quoka_scoring", us_q,
             f"vs_sample_attn={us_s/us_q:.2f}x (paper predicts ~n_q/n_kv="
             f"{NQH/NKV:.1f}x)")
        emit(f"complexity_measured/T{t}/sample_attn_scoring", us_s, "")


if __name__ == "__main__":
    run()
