"""Paper Figure 5(b)/(d): end-to-end time-to-first-token across prompt
lengths (small model, B_CP=128 chunked prefill), dense vs QUOKA, with a
kernel-backend axis recorded in the JSON output (xla vs pallas_interpret on
CPU hosts, xla vs pallas on TPU)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (INTERPRET_MAX_T, backend_axis, emit, header,
                               json_mark, write_json)
from repro.configs import get_config
from repro.models.model import build_model
from repro.serving.engine import Engine

LENGTHS = (1024, 2048, 4096)
SMOKE_LENGTHS = (512, 1024)


def run(lengths=LENGTHS, *, smoke: bool = False):
    """``smoke``: short lengths + a smaller model for the fast CI tier (the
    regression gate compares the quoka/full TTFT ratio, which is stable
    across runner speeds)."""
    header("ttft (Fig 5b/d)")
    mark = json_mark()
    if smoke:
        lengths = SMOKE_LENGTHS
    cfg = get_config("qwen3-4b").smoke(n_layers=4, d_model=256, n_heads=8,
                                       n_kv_heads=2, d_ff=512, vocab=2048)
    cfg = dataclasses.replace(
        cfg, quoka=dataclasses.replace(cfg.quoka, chunk_size=128,
                                       budget=256, n_queries=16))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # block-granular + cross-layer-reuse arm (params are QuokaConfig-free,
    # so the token-granular init serves both models)
    blk_g, blk_s = 16, 2
    model_blk = build_model(dataclasses.replace(
        cfg, quoka=dataclasses.replace(cfg.quoka, granularity=blk_g,
                                       reuse_interval=blk_s)))
    rng = np.random.default_rng(0)
    for t in lengths:
        toks = jnp.asarray(rng.integers(3, cfg.vocab, (1, t)), jnp.int32)
        base = None
        for backend in backend_axis():
            if backend == "pallas_interpret" and t > INTERPRET_MAX_T:
                continue
            for m in ("full", "quoka"):
                if m == "full" and backend != "xla":
                    continue        # dense prefill is backend-free
                eng = Engine(model, params, method=m, backend=backend)
                r = eng.generate({"tokens": toks}, 1)     # warm compile
                r = eng.generate({"tokens": toks}, 1)
                us = r.ttft_s * 1e6
                if m == "full":
                    base = us
                derived = f"speedup={base/us:.2f}x" if base else ""
                emit(f"ttft/T{t}/{backend}/{m}", us, derived,
                     bench="ttft", seq_len=t, backend=backend, method=m,
                     granularity=1, reuse_interval=1)
            if backend == "xla":
                eng = Engine(model_blk, params, method="quoka",
                             backend=backend)
                r = eng.generate({"tokens": toks}, 1)     # warm compile
                r = eng.generate({"tokens": toks}, 1)
                us = r.ttft_s * 1e6
                derived = f"speedup={base/us:.2f}x" if base else ""
                emit(f"ttft/T{t}/{backend}/quoka_g{blk_g}", us, derived,
                     bench="ttft", seq_len=t, backend=backend,
                     method="quoka", granularity=blk_g,
                     reuse_interval=blk_s)
    write_json("ttft", mark)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short lengths for the fast CI tier")
    run(smoke=ap.parse_args().smoke)
