"""Paper Figure 5(b)/(d): end-to-end time-to-first-token across prompt
lengths (small model, B_CP=128 chunked prefill), dense vs QUOKA."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, header
from repro.configs import get_config
from repro.models.model import build_model
from repro.serving.engine import Engine

LENGTHS = (1024, 2048, 4096)


def run():
    header("ttft (Fig 5b/d)")
    cfg = get_config("qwen3-4b").smoke(n_layers=4, d_model=256, n_heads=8,
                                       n_kv_heads=2, d_ff=512, vocab=2048)
    cfg = dataclasses.replace(
        cfg, quoka=dataclasses.replace(cfg.quoka, chunk_size=128,
                                       budget=256, n_queries=16))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    for t in LENGTHS:
        toks = jnp.asarray(rng.integers(3, cfg.vocab, (1, t)), jnp.int32)
        base = None
        for m in ("full", "quoka"):
            eng = Engine(model, params, method=m)
            r = eng.generate({"tokens": toks}, 1)     # warm compile
            r = eng.generate({"tokens": toks}, 1)
            us = r.ttft_s * 1e6
            if m == "full":
                base = us
            emit(f"ttft/T{t}/{m}", us, f"speedup={base/us:.2f}x")


if __name__ == "__main__":
    run()
