"""Paper Tables 1 (RULER) and 3 (LongBench) — accuracy proxies.

No pretrained checkpoints are available offline, so accuracy is proxied at
the attention level on the structured Figure-2 geometry (see
data/synthetic.py): output relative error (eq. 4) and max-oracle key recall,
across prompt lengths (Table 1 axis) and selection budgets (Table 3 axis).
Lower err / higher recall == better; `derived` carries both.
"""
from __future__ import annotations

import jax

from benchmarks.common import emit, header
from repro.configs.base import QuokaConfig
from repro.core.chunked_prefill import (critical_key_recall, key_recall,
                                        output_error)
from repro.data.synthetic import structured_qkv

METHODS = ("quoka", "sample_attention", "sparq", "loki", "less_is_more",
           "snapkv", "keydiff")


def run_lengths():
    """Table 1 proxy: fixed budget, growing prompt length."""
    header("accuracy vs length (Table 1 proxy, B_SA=128)")
    for t in (512, 1024, 2048):
        q, k, v = structured_qkv(jax.random.PRNGKey(3), 2, t, 8, 2, 32,
                                 n_needles=max(16, t // 24))
        cfg = QuokaConfig(chunk_size=128, budget=128, n_queries=16,
                          keep_first=4)
        for m in METHODS:
            e = float(output_error(q, k, v, cfg, m))
            r = float(key_recall(q, k, v, cfg, m))
            c = float(critical_key_recall(q, k, v, cfg, m))
            emit(f"ruler_proxy/T{t}/{m}", 0.0,
                 f"err={e:.4f};recall={r:.3f};critical={c:.3f}")


def run_budgets():
    """Table 3 proxy: fixed length, shrinking selective budget."""
    header("accuracy vs budget (Table 3 proxy, T=1024)")
    q, k, v = structured_qkv(jax.random.PRNGKey(5), 2, 1024, 8, 2, 32,
                             n_needles=48)
    for budget in (64, 128, 256):
        cfg = QuokaConfig(chunk_size=128, budget=budget, n_queries=16,
                          keep_first=4)
        for m in METHODS:
            e = float(output_error(q, k, v, cfg, m))
            r = float(key_recall(q, k, v, cfg, m))
            emit(f"longbench_proxy/B{budget}/{m}", 0.0,
                 f"err={e:.4f};recall={r:.3f}")


def run():
    run_lengths()
    run_budgets()


if __name__ == "__main__":
    run()
