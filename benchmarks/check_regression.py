"""Benchmark-regression gate for the fast CI tier.

Compares the smoke-run JSONs in ``benchmarks/out/`` against committed
baselines in ``benchmarks/baselines/`` and fails (exit 1) when a gated
metric regresses beyond its tolerance band — so the perf trajectory is
recorded AND enforced, not just uploaded as an artifact.

Baseline schema (``benchmarks/baselines/<bench>.json``)::

    {"metrics": [
       {"name": "...",                      # label for the report
        "match": {"mode": "cached", ...},   # fields a record must equal
        "field": "ttft_speedup",            # value under comparison
        "ratio_to": {"method": "full"},     # optional: divide by the same
                                            # field of this other record
        "direction": "higher",              # higher|lower is better
        "baseline": 5.8,                    # committed reference value
        "rel_tol": 0.5,                     # band: value may be up to 50%
                                            # worse than baseline
        "floor": 1.5,                       # optional absolute bound a
                                            # value must never cross,
                                            # regardless of the baseline
        "informational": false}]}           # true: record + report, but an
                                            # out-of-band value does NOT
                                            # fail the gate

Absolute timings vary across CI runners (GitHub VMs differ severalfold in
speed from the machine that recorded the baseline), so only RATIO metrics
(speedups, hit rates) gate the tier; mark absolute-timing metrics
``informational`` — they are still computed, reported and uploaded in the
perf-trajectory artifact.  A GATED metric whose records are missing from
``out/`` fails — a silently skipped scenario must not pass.

    PYTHONPATH=src python -m benchmarks.run --suite serving --smoke
    PYTHONPATH=src python -m benchmarks.check_regression [--update]

``--update`` rewrites the committed ``baseline`` values from the current
``out/`` JSONs (tolerances and floors are kept) — run it on an intended
perf change and commit the refreshed baselines with it.

Records that NO metric selects (and out/ benches with no baseline file)
are reported as GitHub ``::warning`` annotations instead of passing
silently, and when ``$GITHUB_STEP_SUMMARY`` is set the per-metric results
are appended there as a markdown table (the nightly workflow surfaces it
on the run page).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional

HERE = os.path.dirname(__file__)


def _load(path: str):
    with open(path) as f:
        return json.load(f)


def _select(records: List[Dict], match: Dict) -> List[Dict]:
    return [r for r in records
            if all(r.get(k) == v for k, v in match.items())]


def _value(records: List[Dict], metric: Dict) -> Optional[float]:
    """Metric value from the out-JSON records (median over matches), as a
    ratio against ``ratio_to`` records when given.  None = missing."""
    field = metric["field"]
    num = sorted(float(r[field]) for r in _select(records, metric["match"])
                 if field in r)
    if not num:
        return None
    val = num[len(num) // 2]
    if "ratio_to" in metric:
        den = sorted(float(r[field])
                     for r in _select(records, metric["ratio_to"])
                     if field in r)
        if not den or den[len(den) // 2] == 0:
            return None
        val = val / den[len(den) // 2]
    return val


def _ungated(records: List[Dict], metrics: List[Dict]) -> List[str]:
    """Record names in ``records`` that NO metric's ``match`` (or
    ``ratio_to``) selects — scenarios that run in CI but whose results
    nothing gates.  Such records used to pass silently; they are surfaced
    as ``::warning`` annotations so a new benchmark scenario cannot land
    without either a baseline entry or an explicit decision to skip one."""
    gated = set()
    for m in metrics:
        for sel in (m.get("match"), m.get("ratio_to")):
            if sel:
                gated.update(id(r) for r in _select(records, sel))
    return sorted({r.get("name", "<unnamed>") for r in records
                   if id(r) not in gated})


def _write_summary(rows: List[Dict]) -> None:
    """Markdown regression table appended to ``$GITHUB_STEP_SUMMARY`` when
    the env var is set (GitHub renders it on the workflow run page)."""
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if not path or not rows:
        return
    with open(path, "a") as f:
        f.write("## Benchmark regression gate\n\n")
        f.write("| status | metric | value | baseline | notes |\n")
        f.write("|---|---|---|---|---|\n")
        for r in rows:
            mark = {"ok": "✅", "info": "ℹ️",
                    "FAIL": "❌"}.get(r["status"], r["status"])
            f.write(f"| {mark} {r['status']} | {r['bench']}/{r['name']} "
                    f"| {r['value']} | {r['baseline']} "
                    f"| {'; '.join(r['reasons'])} |\n")


def _check(metric: Dict, value: Optional[float]) -> List[str]:
    """Failure reasons ([] = pass)."""
    if value is None:
        return ["metric missing from benchmark output"]
    higher = metric.get("direction", "higher") == "higher"
    base = float(metric["baseline"])
    tol = float(metric.get("rel_tol", 0.5))
    fails = []
    bound = base * (1.0 - tol) if higher else base * (1.0 + tol)
    if higher and value < bound:
        fails.append(f"{value:.4g} < tolerance bound {bound:.4g} "
                     f"(baseline {base:.4g}, rel_tol {tol})")
    if not higher and value > bound:
        fails.append(f"{value:.4g} > tolerance bound {bound:.4g} "
                     f"(baseline {base:.4g}, rel_tol {tol})")
    if "floor" in metric and higher and value < float(metric["floor"]):
        fails.append(f"{value:.4g} < hard floor {metric['floor']}")
    if "cap" in metric and not higher and value > float(metric["cap"]):
        fails.append(f"{value:.4g} > hard cap {metric['cap']}")
    return fails


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.join(HERE, "out"),
                    help="directory of fresh benchmark JSONs")
    ap.add_argument("--baselines", default=os.path.join(HERE, "baselines"),
                    help="directory of committed baseline JSONs")
    ap.add_argument("--update", action="store_true",
                    help="rewrite baseline values from the current out/ "
                         "JSONs instead of checking")
    args = ap.parse_args()

    names = sorted(f[:-5] for f in os.listdir(args.baselines)
                   if f.endswith(".json"))
    if not names:
        print("no baselines committed; nothing to gate", file=sys.stderr)
        return 1
    failures = 0
    summary_rows: List[Dict] = []
    if os.path.isdir(args.out):
        for extra in sorted(
                set(f[:-5] for f in os.listdir(args.out)
                    if f.endswith(".json")) - set(names)):
            try:
                recs = _load(os.path.join(args.out, f"{extra}.json"))
            except (json.JSONDecodeError, OSError):
                continue
            # only record lists count — out/ also holds auxiliary JSON
            # (Chrome traces, trajectory history) that nothing should gate
            if (isinstance(recs, list) and recs
                    and all(isinstance(r, dict) and "name" in r
                            for r in recs)):
                print(f"::warning title=ungated benchmark::{extra}: output "
                      f"in {args.out} but no baseline file gates it")
    for bench in names:
        bpath = os.path.join(args.baselines, f"{bench}.json")
        opath = os.path.join(args.out, f"{bench}.json")
        baseline = _load(bpath)
        records = _load(opath) if os.path.exists(opath) else []
        if not records:
            print(f"FAIL {bench}: no benchmark output at {opath}")
            failures += 1
            continue
        for metric in baseline["metrics"]:
            value = _value(records, metric)
            if args.update:
                if value is None:
                    print(f"FAIL {bench}/{metric['name']}: cannot update, "
                          f"metric missing from output")
                    failures += 1
                else:
                    metric["baseline"] = round(value, 6)
                    print(f"set  {bench}/{metric['name']} = {value:.4g}")
                continue
            reasons = _check(metric, value)
            info = bool(metric.get("informational"))
            status = ("info" if info and reasons
                      else "FAIL" if reasons else "ok")
            shown = "missing" if value is None else f"{value:.4g}"
            print(f"{status:4s} {bench}/{metric['name']}: {shown} "
                  f"(baseline {metric['baseline']}, "
                  f"{metric.get('direction', 'higher')} is better"
                  f"{', informational' if info else ''})")
            for r in reasons:
                print(f"     -> {r}")
            failures += bool(reasons) and not info
            summary_rows.append(dict(status=status, bench=bench,
                                     name=metric["name"], value=shown,
                                     baseline=metric["baseline"],
                                     reasons=reasons))
        if not args.update:
            loose = _ungated(records, baseline["metrics"])
            if loose:
                print(f"::warning title=ungated benchmark records::{bench}: "
                      f"{len(loose)} record name(s) matched by no baseline "
                      f"metric: {', '.join(loose)}")
        if args.update:
            with open(bpath, "w") as f:
                json.dump(baseline, f, indent=2)
                f.write("\n")
    _write_summary(summary_rows)
    if failures:
        print(f"\n{failures} regression(s) beyond tolerance", file=sys.stderr)
        return 1
    print("\nall gated metrics within tolerance")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
