"""§Roofline: render the dry-run JSONs (experiments/dryrun/*.json) into the
EXPERIMENTS.md table — three terms, dominant bottleneck, MODEL_FLOPS ratio.
Run after `python -m repro.launch.dryrun --all --both-meshes`.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit, header

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_rows(mesh: str = "16x16"):
    """Canonical baseline artifacts only — §Perf iteration files carry a
    `_perf*`/`_donate`/`_chunkwise`/`_full` suffix and are excluded."""
    rows = {}
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        base = os.path.basename(path)[:-5]
        if not base.endswith("_" + mesh):
            continue
        with open(path) as f:
            r = json.load(f)
        if r.get("mesh") == mesh:
            rows[(r["arch"], r["shape"])] = r
    return [rows[k] for k in sorted(rows)]


def markdown_table(rows):
    hdr = ("| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | "
           "bottleneck | useful | mem/chip (GB) |")
    sep = "|" + "---|" * 8
    out = [hdr, sep]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']*1e3:.2f} | "
            f"{r['t_memory']*1e3:.2f} | {r['t_collective']*1e3:.2f} | "
            f"{r['bottleneck']} | {r['useful_ratio']:.2f} | "
            f"{r['bytes_per_chip']/1e9:.1f} |")
    return "\n".join(out)


def run():
    header("roofline table (from dry-run artifacts)")
    rows = load_rows("16x16")
    if not rows:
        emit("roofline/missing", 0.0, "run repro.launch.dryrun --all first")
        return
    print(markdown_table(rows))
    for r in rows:
        emit(f"roofline/{r['arch']}/{r['shape']}",
             max(r["t_compute"], r["t_memory"], r["t_collective"]) * 1e6,
             f"bottleneck={r['bottleneck']};useful={r['useful_ratio']:.2f}")


if __name__ == "__main__":
    run()
