"""Paper Figure 4 / §4.1: Needle-In-A-Haystack with a scratch-trained model.

Trains a small retrieval model once (a few hundred steps), then evaluates
chunked-prefill retrieval accuracy across needle depths and prompt lengths
for QUOKA vs dense vs baselines.  This is the end-to-end accuracy claim the
attention-level proxies cannot capture.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks.common import emit, header
from repro.configs import get_config
from repro.data.synthetic import needle_accuracy, needle_batch, needle_batches
from repro.models.model import build_model
from repro.training import loop as train_loop
from repro.training import optimizer as opt

METHODS = ("full", "quoka", "sample_attention", "sparq", "keydiff")


def train_model(steps: int = 300):
    cfg = get_config("llama3-2-3b").smoke(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256, vocab=256)
    cfg = dataclasses.replace(
        cfg, quoka=dataclasses.replace(cfg.quoka, chunk_size=32, budget=48,
                                       n_queries=8, keep_first=4))
    model = build_model(cfg)
    gen = needle_batches(jax.random.PRNGKey(0), cfg.vocab, 16, 97,
                         n_keys=16, n_distractors=2)
    state, _ = train_loop.train(
        model, gen, steps=steps, log_every=100,
        ocfg=opt.OptimizerConfig(lr=3e-3, warmup_steps=20, total_steps=steps))
    return model, state.params, cfg


def run(steps: int = 300):
    header("NIAH (Fig 4): scratch-trained retrieval model, "
           "RULER-style distractor needles")
    model, params, cfg = train_model(steps)
    rng = np.random.default_rng(0)
    for t in (97, 161, 321):
        for depth in (0.1, 0.5, 0.9):
            batch = needle_batch(rng, cfg.vocab, 16, t, n_keys=16,
                                 depth=depth, n_distractors=4)
            accs = {}
            for m in METHODS:
                accs[m] = needle_accuracy(model, params, batch, m)
            emit(f"niah/T{t}/depth{depth}", 0.0,
                 ";".join(f"{m}={accs[m]:.2f}" for m in METHODS))


if __name__ == "__main__":
    run()
