"""Paper Figure 5(a)/(c): standalone attention-module latency across prompt
lengths — dense chunked prefill vs QUOKA vs the strongest baselines, with a
KERNEL-BACKEND axis (xla vs pallas_interpret; "pallas" on a real TPU) so the
JSON output records xla-vs-kernel trajectories per length.

This container is a CPU host, matching the paper's Intel-Xeon setting
(Fig 5c); `derived` reports the speedup over dense at each length.  The
interpreted Pallas backend executes the kernel body per grid cell in Python
— it validates the dispatch path, not kernel speed — so it only runs at the
shortest length (`INTERPRET_MAX_T`).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from benchmarks.common import (INTERPRET_MAX_T, backend_axis, emit, header,
                               json_mark, time_fn, write_json)
from repro.configs.base import QuokaConfig
from repro.core.chunked_prefill import chunked_sparse_attention

LENGTHS = (1024, 2048, 4096, 8192)
METHODS = ("full", "quoka", "sample_attention", "sparq")
H, NKV, D = 16, 4, 64           # qwen3-4b-ish head geometry (scaled)
BLOCK_G = 16                    # block-granular selection grid arm


def run(lengths=LENGTHS):
    header("attn_latency (Fig 5a/c)")
    mark = json_mark()
    key = jax.random.PRNGKey(0)
    cfg = QuokaConfig(chunk_size=128, budget=1024, n_queries=16)
    for t in lengths:
        q = jax.random.normal(key, (1, t, H, D), jnp.float32)
        k = jax.random.normal(jax.random.fold_in(key, 1), (1, t, NKV, D))
        v = jax.random.normal(jax.random.fold_in(key, 2), (1, t, NKV, D))
        for backend in backend_axis():
            if backend == "pallas_interpret" and t > INTERPRET_MAX_T:
                continue
            iters = 1 if backend == "pallas_interpret" else 3
            base_us = None
            for m in METHODS:
                if m == "full" and backend != "xla":
                    continue        # dense reference is backend-free
                # backend passed EXPLICITLY so the recorded label always
                # matches what ran (an exported REPRO_BACKEND would
                # otherwise override cfg.backend)
                fn = jax.jit(functools.partial(
                    chunked_sparse_attention, cfg=cfg, method=m,
                    backend=backend))
                us = time_fn(fn, q, k, v, warmup=1, iters=iters)
                if m == "full":
                    base_us = us
                derived = f"speedup={base_us/us:.2f}x" if base_us else ""
                emit(f"attn_latency/T{t}/{backend}/{m}", us, derived,
                     bench="attn_latency", seq_len=t, backend=backend,
                     method=m, granularity=1, reuse_interval=1)
            if backend == "xla":
                # block-granular quoka arm (SelectionPlan on a 16-token
                # grid); the gated baselines pin granularity=1, this arm
                # tracks the contiguous-gather trajectory
                cfg_blk = dataclasses.replace(cfg, granularity=BLOCK_G)
                fn = jax.jit(functools.partial(
                    chunked_sparse_attention, cfg=cfg_blk, method="quoka",
                    backend=backend))
                us = time_fn(fn, q, k, v, warmup=1, iters=iters)
                derived = f"speedup={base_us/us:.2f}x" if base_us else ""
                emit(f"attn_latency/T{t}/{backend}/quoka_g{BLOCK_G}", us,
                     derived, bench="attn_latency", seq_len=t,
                     backend=backend, method="quoka", granularity=BLOCK_G,
                     reuse_interval=1)
    write_json("attn_latency", mark)


if __name__ == "__main__":
    run()
