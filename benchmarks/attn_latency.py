"""Paper Figure 5(a)/(c): standalone attention-module latency across prompt
lengths — dense chunked prefill vs QUOKA vs the strongest baselines, with a
KERNEL-BACKEND axis (xla vs pallas_interpret; "pallas" on a real TPU) so the
JSON output records xla-vs-kernel trajectories per length.

This container is a CPU host, matching the paper's Intel-Xeon setting
(Fig 5c); `derived` reports the speedup over dense at each length.  The
interpreted Pallas backend executes the kernel body per grid cell in Python
— it validates the dispatch path, not kernel speed — so it only runs at the
shortest length (`INTERPRET_MAX_T`).

Besides the end-to-end sweep, a per-STAGE breakdown runs at the last-chunk
geometry of ``STAGE_T`` (the hardest selection: chunk queries against the
full prior cache): score (plan build), materialize (budget gather) and
attend are timed as separate jitted calls — stage wall times are only
observable at dispatch granularity — and recorded per arm:

  staged  ``fused=False``: stage_mat_attend_us = the materialize call
          followed by the attend call (two dispatches + the materialized
          ``Selected`` buffers between them — the staged pipeline's cost
          shape).
  fused   ``fused=True``: stage_mat_attend_us = ONE ``ops.selected_attention``
          call straight off the plan indices (stage_materialize_us == 0 by
          construction); ``mat_attend_ratio`` = fused / staged, measured by
          PAIRED sampling (per-iteration ratio of back-to-back calls, median
          — immune to the machine-load drift that independent medians pick
          up).  This ratio is the regression-gated fused-path headline
          (benchmarks/baselines/attn_latency.json); absolute stage times are
          informational.

    PYTHONPATH=src python -m benchmarks.attn_latency [--smoke]
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import time

import jax
import jax.numpy as jnp

from benchmarks.common import (INTERPRET_MAX_T, backend_axis, emit, header,
                               json_mark, time_fn, write_json)
from repro.configs.base import QuokaConfig
from repro.core import plan as plan_mod
from repro.core.chunked_prefill import chunked_sparse_attention
from repro.kernels import ops as kops

LENGTHS = (1024, 2048, 4096, 8192)
METHODS = ("full", "quoka", "sample_attention", "sparq")
H, NKV, D = 16, 4, 64           # qwen3-4b-ish head geometry (scaled)
BLOCK_G = 16                    # block-granular selection grid arm
STAGE_T = 1024                  # per-stage breakdown / fused-gate geometry


def _paired_ratio(fn_a, fn_b, iters: int) -> float:
    """Median over iterations of (one fn_a call) / (one fn_b call), the
    calls interleaved back to back so slow drift hits both sides of every
    ratio equally."""
    ratios = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a())
        ta = time.perf_counter() - t0
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b())
        ratios.append(ta / (time.perf_counter() - t0))
    ratios.sort()
    return ratios[len(ratios) // 2]


def _stage_breakdown(key, t: int, backend: str):
    """Score / materialize / attend wall times at the last-chunk geometry,
    staged vs fused, on one backend leg."""
    cfg = QuokaConfig(chunk_size=128, budget=1024, n_queries=16,
                      granularity=BLOCK_G, backend=backend)
    chunk = cfg.chunk_size
    q = jax.random.normal(key, (1, t, H, D), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, t, NKV, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, t, NKV, D))
    pos = jnp.arange(t, dtype=jnp.int32)[None]
    start = jnp.asarray(t - chunk, jnp.int32)
    qc, kc, vc = q[:, t - chunk:], k[:, t - chunk:], v[:, t - chunk:]
    g = plan_mod.grid(cfg)
    iters = 3 if backend == "pallas_interpret" else 9

    build_j = jax.jit(functools.partial(plan_mod.build, "quoka", cfg=cfg))
    pln = jax.block_until_ready(build_j(qc, k, pos, start))
    mat_j = jax.jit(functools.partial(plan_mod.materialize, cfg=cfg))
    sel = jax.block_until_ready(mat_j(pln, k, v, pos, start))
    boundary = sel.pos.shape[-1]

    def attend(sel_k, sel_v, sel_pos, qc, kc, vc):
        b = qc.shape[0]
        k_cat = jnp.concatenate([sel_k, kc], axis=1)
        v_cat = jnp.concatenate([sel_v, vc], axis=1)
        k_valid = jnp.concatenate(
            [sel_pos >= 0, jnp.ones((b, NKV, chunk), bool)], axis=-1)
        return kops.attention(qc, k_cat, v_cat, k_valid, causal=True,
                              boundary=boundary, backend=backend)

    att_j = jax.jit(attend)
    fused_j = jax.jit(functools.partial(
        kops.selected_attention, granularity=g, backend=backend, cfg=cfg))

    def staged_mat_attend():
        s = mat_j(pln, k, v, pos, start)
        return att_j(s.k, s.v, s.pos, qc, kc, vc)

    def fused_mat_attend():
        return fused_j(qc, k, v, pos, pln.idx, start)

    score_us = time_fn(build_j, qc, k, pos, start, warmup=1, iters=iters)
    mat_us = time_fn(mat_j, pln, k, v, pos, start, warmup=1, iters=iters)
    att_us = time_fn(att_j, sel.k, sel.v, sel.pos, qc, kc, vc,
                     warmup=1, iters=iters)
    staged_us = time_fn(staged_mat_attend, warmup=1, iters=iters)
    fused_us = time_fn(fused_mat_attend, warmup=1, iters=iters)
    ratio = _paired_ratio(fused_mat_attend, staged_mat_attend, iters)

    common = dict(bench="attn_latency", scenario="stage", seq_len=t,
                  backend=backend, method="quoka", granularity=BLOCK_G,
                  reuse_interval=1)
    emit(f"attn_latency/stage/T{t}/{backend}/staged",
         score_us + staged_us, f"mat+attend={staged_us:.0f}us",
         fused=False, stage_score_us=score_us, stage_materialize_us=mat_us,
         stage_attend_us=att_us, stage_mat_attend_us=staged_us, **common)
    emit(f"attn_latency/stage/T{t}/{backend}/fused",
         score_us + fused_us, f"fused/staged={ratio:.3f}",
         fused=True, stage_score_us=score_us, stage_materialize_us=0.0,
         stage_attend_us=fused_us, stage_mat_attend_us=fused_us,
         mat_attend_ratio=ratio, **common)


def run(lengths=LENGTHS, smoke: bool = False):
    header("attn_latency (Fig 5a/c)")
    if smoke:
        lengths = (STAGE_T,)
    mark = json_mark()
    key = jax.random.PRNGKey(0)
    cfg = QuokaConfig(chunk_size=128, budget=1024, n_queries=16)
    for t in lengths:
        q = jax.random.normal(key, (1, t, H, D), jnp.float32)
        k = jax.random.normal(jax.random.fold_in(key, 1), (1, t, NKV, D))
        v = jax.random.normal(jax.random.fold_in(key, 2), (1, t, NKV, D))
        for backend in backend_axis():
            if backend == "pallas_interpret" and t > INTERPRET_MAX_T:
                continue
            iters = 1 if backend == "pallas_interpret" else 3
            base_us = None
            for m in METHODS:
                if m == "full" and backend != "xla":
                    continue        # dense reference is backend-free
                # backend passed EXPLICITLY so the recorded label always
                # matches what ran (an exported REPRO_BACKEND would
                # otherwise override cfg.backend)
                fn = jax.jit(functools.partial(
                    chunked_sparse_attention, cfg=cfg, method=m,
                    backend=backend))
                us = time_fn(fn, q, k, v, warmup=1, iters=iters)
                if m == "full":
                    base_us = us
                derived = f"speedup={base_us/us:.2f}x" if base_us else ""
                emit(f"attn_latency/T{t}/{backend}/{m}", us, derived,
                     bench="attn_latency", scenario="e2e", seq_len=t,
                     backend=backend, method=m, granularity=1,
                     reuse_interval=1, fused=False)
            if backend == "xla":
                # block-granular quoka arms (SelectionPlan on a 16-token
                # grid), staged vs fused-routed; the gated baselines pin
                # granularity=1, these arms track the contiguous-gather
                # and gather-free trajectories
                for fused in (False, True):
                    cfg_blk = dataclasses.replace(
                        cfg, granularity=BLOCK_G, fused_select_attn=fused)
                    fn = jax.jit(functools.partial(
                        chunked_sparse_attention, cfg=cfg_blk,
                        method="quoka", backend=backend))
                    us = time_fn(fn, q, k, v, warmup=1, iters=iters)
                    derived = f"speedup={base_us/us:.2f}x" if base_us else ""
                    label = f"quoka_g{BLOCK_G}" + ("_fused" if fused else "")
                    emit(f"attn_latency/T{t}/{backend}/{label}", us,
                         derived, bench="attn_latency", scenario="e2e",
                         seq_len=t, backend=backend, method="quoka",
                         granularity=BLOCK_G, reuse_interval=1, fused=fused)
        if t == STAGE_T:
            for backend in backend_axis():
                if backend == "pallas_interpret" and t > INTERPRET_MAX_T:
                    continue
                _stage_breakdown(jax.random.fold_in(key, 7), t, backend)
    write_json("attn_latency", mark)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="T=1024 only (the regression-gated stage geometry) "
                         "for the fast CI tier")
    args = ap.parse_args()
    run(smoke=args.smoke)


if __name__ == "__main__":
    main()
