"""Paper Figure 5(a)/(c): standalone attention-module latency across prompt
lengths — dense chunked prefill vs QUOKA vs the strongest baselines.

This container is a CPU host, matching the paper's Intel-Xeon setting
(Fig 5c); `derived` reports the speedup over dense at each length.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import emit, header, time_fn
from repro.configs.base import QuokaConfig
from repro.core.chunked_prefill import chunked_sparse_attention

LENGTHS = (1024, 2048, 4096, 8192)
METHODS = ("full", "quoka", "sample_attention", "sparq")
H, NKV, D = 16, 4, 64           # qwen3-4b-ish head geometry (scaled)


def run():
    header("attn_latency (Fig 5a/c)")
    key = jax.random.PRNGKey(0)
    cfg = QuokaConfig(chunk_size=128, budget=1024, n_queries=16)
    for t in LENGTHS:
        q = jax.random.normal(key, (1, t, H, D), jnp.float32)
        k = jax.random.normal(jax.random.fold_in(key, 1), (1, t, NKV, D))
        v = jax.random.normal(jax.random.fold_in(key, 2), (1, t, NKV, D))
        base_us = None
        for m in METHODS:
            fn = jax.jit(functools.partial(
                chunked_sparse_attention, cfg=cfg, method=m))
            us = time_fn(fn, q, k, v, iters=3)
            if m == "full":
                base_us = us
            emit(f"attn_latency/T{t}/{m}", us,
                 f"speedup={base_us/us:.2f}x")


if __name__ == "__main__":
    run()
