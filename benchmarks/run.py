"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.py); every
module also writes structured JSON to ``benchmarks/out/<name>.json``.

  attn_latency     Figure 5(a)/(c)  attention-module latency vs length
  ttft             Figure 5(b)/(d)  end-to-end time-to-first-token
  decode_latency   Figure 6         decode-step latency vs cache length
  accuracy_proxy   Tables 1 & 3     RULER/LongBench attention-level proxies
  niah             Figure 4         scratch-trained needle retrieval
  ablations        Tables 9-12      scoring / aggregation / B_CP / N_Q
  complexity       Table 4          analytic + measured scoring complexity
  roofline_table   EXPERIMENTS §Roofline (from dry-run artifacts)
  serving_throughput  §4.6 under load: continuous batching vs one-at-a-time
                      + the prefix_reuse (cache-hit TTFT) scenario

Suites bundle related benchmarks:

  --suite serving  serving_throughput (throughput + prefix_reuse) + ttft —
                   the set the CI regression gate checks
                   (benchmarks/check_regression.py); combine with --smoke
                   for the fast-tier geometry.
"""
import argparse
import json
import os
import subprocess
import sys
import time
import traceback

SUITES = {
    "serving": ("serving_throughput", "ttft"),
}

TRAJECTORY = os.path.join(os.path.dirname(__file__), "out",
                          "BENCH_trajectory.json")


def _append_trajectory(ran, failures) -> None:
    """Append one compact record per driver run to BENCH_trajectory.json
    (a list; benchmarks/out/ is gitignored — full out/*.json dumps are NOT
    committed, CI uploads the whole directory as the perf-trajectory
    artifact instead).  The record keeps the machine-readable headline —
    every emitted metric's name -> us_per_call — plus enough provenance
    (time, commit, argv) to line trajectories up across PRs."""
    from benchmarks.common import RECORDS
    try:
        commit = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__))).stdout.strip()
    except Exception:
        commit = ""
    rec = {
        "time": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "commit": commit or None,
        "argv": sys.argv[1:],
        "ran": sorted(ran),
        "failed": sorted(failures),
        "metrics": {r["name"]: r["us_per_call"] for r in RECORDS},
    }
    os.makedirs(os.path.dirname(TRAJECTORY), exist_ok=True)
    history = []
    if os.path.exists(TRAJECTORY):
        try:
            with open(TRAJECTORY) as f:
                history = json.load(f)
            assert isinstance(history, list)
        except Exception:
            history = []        # corrupt file: restart, don't crash the run
    history.append(rec)
    with open(TRAJECTORY, "w") as f:
        json.dump(history, f, indent=1)
    print(f"# appended run record -> {TRAJECTORY} "
          f"({len(history)} runs)", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benchmark names")
    ap.add_argument("--suite", default=None, choices=sorted(SUITES),
                    help="named benchmark bundle (e.g. 'serving' runs "
                         "throughput + ttft + prefix_reuse in one go)")
    ap.add_argument("--fast", action="store_true",
                    help="skip the slow trained-model NIAH benchmark")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny geometries for the fast CI tier (benchmarks "
                         "that support it)")
    args = ap.parse_args()

    from benchmarks import (ablations, accuracy_proxy, attn_latency,
                            complexity, decode_latency, niah, roofline_table,
                            serving_throughput, ttft)
    smoke = {"smoke": True} if args.smoke else {}
    todo = {
        "attn_latency": lambda: attn_latency.run(**smoke),
        "ttft": lambda: ttft.run(**smoke),
        "decode_latency": decode_latency.run,
        "accuracy_proxy": accuracy_proxy.run,
        "ablations": ablations.run,
        "complexity": complexity.run,
        "niah": niah.run,
        "roofline_table": roofline_table.run,
        "serving_throughput": lambda: serving_throughput.run(**smoke),
    }
    if args.fast:
        todo.pop("niah")
    keep = set()
    if args.suite:
        keep |= set(SUITES[args.suite])
    if args.only:
        keep |= set(args.only.split(","))
    if keep:
        todo = {k: v for k, v in todo.items() if k in keep}
    print("name,us_per_call,derived")
    failures = []
    for name, fn in todo.items():
        try:
            fn()
        except Exception:
            failures.append(name)
            traceback.print_exc()
    _append_trajectory(todo.keys(), failures)
    if failures:
        print(f"FAILED benchmarks: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == '__main__':
    main()
