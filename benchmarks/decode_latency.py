"""Paper Figure 6: decode-step latency with a long cached context —
dense attention over the full cache vs QUOKA selection."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, header, time_fn
from repro.configs import get_config
from repro.models.model import build_model

CACHE_LENS = (2048, 4096, 8192)


def run():
    header("decode_latency (Fig 6)")
    cfg = get_config("qwen3-4b").smoke(n_layers=4, d_model=256, n_heads=8,
                                       n_kv_heads=2, d_ff=512, vocab=2048)
    cfg = dataclasses.replace(
        cfg, quoka=dataclasses.replace(cfg.quoka, budget=512, chunk_size=128))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    for t in CACHE_LENS:
        toks = jnp.asarray(rng.integers(3, cfg.vocab, (4, t)), jnp.int32)
        cache = model.init_cache(4, t + 8)
        _, cache = jax.jit(lambda p, b, c: model.prefill(p, b, c, "full"))(
            params, {"tokens": toks}, cache)
        tok = jnp.zeros((4,), jnp.int32)
        base = None
        for m in ("full", "quoka"):
            step = jax.jit(
                lambda p, tk, c, m=m: model.decode_step(p, tk, t, c, m))
            us = time_fn(lambda p, tk, c: step(p, tk, c)[0],
                         params, tok, cache, iters=5)
            if m == "full":
                base = us
            emit(f"decode/T{t}/{m}", us, f"speedup={base/us:.2f}x")


if __name__ == "__main__":
    run()
