"""Paper Tables 9-12 ablations:

  Table 9   scoring:      cosine vs dot product
  Table 10  aggregation:  max vs mean over the query axis
  Table 11  B_CP sweep:   chunk size robustness
  Table 12  N_Q sweep:    number of sub-selected queries
"""
from __future__ import annotations

import jax

from benchmarks.common import emit, header
from repro.configs.base import QuokaConfig
from repro.core.chunked_prefill import key_recall, output_error
from repro.data.synthetic import structured_qkv

QKV = None


def _qkv():
    global QKV
    if QKV is None:
        QKV = structured_qkv(jax.random.PRNGKey(9), 2, 1024, 8, 2, 32,
                             n_needles=48)
    return QKV


def _eval(cfg):
    q, k, v = _qkv()
    return (float(output_error(q, k, v, cfg, "quoka")),
            float(key_recall(q, k, v, cfg, "quoka")))


def run():
    header("ablation: scoring (Table 9)")
    for scoring in ("cosine", "dot"):
        e, r = _eval(QuokaConfig(chunk_size=128, budget=128, n_queries=16,
                                 keep_first=4, scoring=scoring))
        emit(f"ablation_scoring/{scoring}", 0.0, f"err={e:.4f};recall={r:.3f}")

    header("ablation: query aggregation (Table 10)")
    for agg in ("max", "mean"):
        e, r = _eval(QuokaConfig(chunk_size=128, budget=128, n_queries=16,
                                 keep_first=4, query_agg=agg))
        emit(f"ablation_agg/{agg}", 0.0, f"err={e:.4f};recall={r:.3f}")

    header("ablation: chunk size B_CP (Table 11)")
    for bcp in (64, 128, 256, 512):
        e, r = _eval(QuokaConfig(chunk_size=bcp, budget=128,
                                 n_queries=max(4, bcp // 8), keep_first=4))
        emit(f"ablation_bcp/{bcp}", 0.0, f"err={e:.4f};recall={r:.3f}")

    header("ablation: subselected queries N_Q (Table 12)")
    for nq in (4, 8, 16, 32, 64, 128):
        e, r = _eval(QuokaConfig(chunk_size=128, budget=128, n_queries=nq,
                                 keep_first=4))
        emit(f"ablation_nq/{nq}", 0.0, f"err={e:.4f};recall={r:.3f}")


if __name__ == "__main__":
    run()
