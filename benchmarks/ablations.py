"""Paper Tables 9-12 ablations, plus SelectionPlan knobs:

  Table 9   scoring:      cosine vs dot product
  Table 10  aggregation:  max vs mean over the query axis
  Table 11  B_CP sweep:   chunk size robustness
  Table 12  N_Q sweep:    number of sub-selected queries
  extra     granularity:  token vs block selection plans (core/plan.py)
  extra     score_proj:   low-rank scoring dim ablation (kernels/ops.score)

``--only <section> [--smoke]`` runs one section (CI runs
``--only granularity --smoke`` as the selection-granularity gate: block
plans must stay within a bounded output-error delta of token plans).
"""
from __future__ import annotations

import argparse

import jax

from benchmarks.common import emit, header, json_mark, write_json
from repro.configs.base import QuokaConfig
from repro.core.chunked_prefill import key_recall, output_error
from repro.data.synthetic import structured_qkv

QKV = None


def _qkv():
    global QKV
    if QKV is None:
        QKV = structured_qkv(jax.random.PRNGKey(9), 2, 1024, 8, 2, 32,
                             n_needles=48)
    return QKV


def _eval(cfg, qkv=None):
    q, k, v = qkv or _qkv()
    return (float(output_error(q, k, v, cfg, "quoka")),
            float(key_recall(q, k, v, cfg, "quoka")))


def _emit(section, label, e, r, **fields):
    emit(f"ablation_{section}/{label}", 0.0, f"err={e:.4f};recall={r:.3f}",
         bench="ablations", section=section, output_error=e, key_recall=r,
         **fields)


def granularity(smoke: bool = False):
    """Token vs block selection plans: whole-block top-k trades a bounded
    accuracy-proxy delta for contiguous gathers (the smoke variant is the
    CI ``selection-granularity`` gate)."""
    header("ablation: selection granularity (SelectionPlan block plans)")
    if smoke:
        qkv = structured_qkv(jax.random.PRNGKey(9), 1, 256, 4, 2, 32,
                             n_needles=12)
        grids, budget, chunk = (1, 16), 64, 64
    else:
        qkv = _qkv()
        grids, budget, chunk = (1, 8, 16, 32), 128, 128
    err_tok = None
    for g in grids:
        e, r = _eval(QuokaConfig(chunk_size=chunk, budget=budget,
                                 n_queries=16, keep_first=4, granularity=g),
                     qkv)
        _emit("granularity", str(g), e, r, granularity=g, reuse_interval=1)
        if g == 1:
            err_tok = e
    assert e <= err_tok + 0.25, (
        f"block-granular selection diverged from token-granular: "
        f"err {e:.4f} vs {err_tok:.4f}")


def score_proj(smoke: bool = False):
    """Low-rank scoring (kernels/ops.score ``proj``): rank vs accuracy."""
    header("ablation: low-rank scoring dim (score_proj_dim)")
    dims = (0, 16) if smoke else (0, 8, 16, 24)
    for r_dim in dims:
        e, r = _eval(QuokaConfig(chunk_size=128, budget=128, n_queries=16,
                                 keep_first=4, score_proj_dim=r_dim))
        _emit("score_proj", str(r_dim), e, r, score_proj_dim=r_dim)


def run(only: str = None, smoke: bool = False):
    mark = json_mark()
    if only in (None, "scoring"):
        header("ablation: scoring (Table 9)")
        for scoring in ("cosine", "dot"):
            e, r = _eval(QuokaConfig(chunk_size=128, budget=128,
                                     n_queries=16, keep_first=4,
                                     scoring=scoring))
            _emit("scoring", scoring, e, r, scoring=scoring)

    if only in (None, "agg"):
        header("ablation: query aggregation (Table 10)")
        for agg in ("max", "mean"):
            e, r = _eval(QuokaConfig(chunk_size=128, budget=128,
                                     n_queries=16, keep_first=4,
                                     query_agg=agg))
            _emit("agg", agg, e, r, query_agg=agg)

    if only in (None, "bcp"):
        header("ablation: chunk size B_CP (Table 11)")
        for bcp in (64, 128, 256, 512):
            e, r = _eval(QuokaConfig(chunk_size=bcp, budget=128,
                                     n_queries=max(4, bcp // 8),
                                     keep_first=4))
            _emit("bcp", str(bcp), e, r, chunk_size=bcp)

    if only in (None, "nq"):
        header("ablation: subselected queries N_Q (Table 12)")
        for nq in (4, 8, 16, 32, 64, 128):
            e, r = _eval(QuokaConfig(chunk_size=128, budget=128,
                                     n_queries=nq, keep_first=4))
            _emit("nq", str(nq), e, r, n_queries=nq)

    if only in (None, "granularity"):
        granularity(smoke=smoke)

    if only in (None, "score_proj"):
        score_proj(smoke=smoke)

    write_json("ablations", mark)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=["scoring", "agg", "bcp", "nq", "granularity",
                             "score_proj"],
                    help="run a single ablation section")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced sizes for the fast CI tier")
    args = ap.parse_args()
    run(only=args.only, smoke=args.smoke)
