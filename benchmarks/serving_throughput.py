"""Continuous-batching serving throughput: the paper's TTFT story measured
UNDER LOAD instead of in isolation.  A Poisson request trace is served (a)
by the continuous engine (paged KV pool + chunked-prefill/decode scheduler)
and (b) one request at a time (FCFS, per-request generate) — reporting
aggregate tokens/s, p50/p99 TTFT and mean decode-batch occupancy.

A second scenario, ``prefix_reuse``, measures what prefix caching buys in
the regime it targets (shared system prompts / repeated multi-turn
prefixes): the same shared-prefix trace is served twice over one warm pool
— the first pass prefills everything cold, the second hits the cache and
prefills only the uncached suffixes — reporting TTFT and tokens/s for
both, plus the cache hit rate.  The cached/cold TTFT speedup is the
regression-gated headline (benchmarks/check_regression.py).

A third scenario, ``host_offload``, undersizes the device pool so every
finished request's prefix blocks are evicted before the trace repeats,
and compares the re-send's TTFT with the hierarchical pool's host tier on
(eviction demotes to host memory; the re-send promotes) vs off (the
re-send prefills cold).  Its TTFT speedup is also regression-gated.

A fourth scenario, ``multi_tenant_slo``, serves a background tenant's
long decodes alongside an interactive tenant's short deadline-carrying
prompts under FCFS vs SLOPolicy (EDF admission + preemption via block
suspend/resume): the interactive p99 TTFT ratio (fcfs / slo) is the
regression-gated headline for the policy control plane.

    PYTHONPATH=src python -m benchmarks.serving_throughput [--smoke]

Emits JSON to benchmarks/out/serving_throughput.json like attn_latency/ttft.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from benchmarks.common import emit, header, json_mark, write_json
from repro.configs import get_config
from repro.models.model import build_model
from repro.serving.engine import Engine
from repro.serving.request import make_requests


def _trace(rng, vocab, n_requests, len_lo, len_hi, rate):
    """Random-length prompts with Poisson arrivals (rate req/s; inf = all
    at t=0)."""
    lens = rng.integers(len_lo, len_hi + 1, n_requests)
    prompts = [rng.integers(3, vocab, (int(n),)).astype(np.int32)
               for n in lens]
    if np.isinf(rate):
        arrivals = np.zeros(n_requests)
    else:
        arrivals = np.cumsum(rng.exponential(1.0 / rate, n_requests))
    return prompts, arrivals


def _sequential(eng, prompts, arrivals, max_new):
    """FCFS, one request at a time; TTFT includes queueing delay."""
    t0 = time.perf_counter()
    ttfts, generated = [], 0
    for prompt, arr in zip(prompts, arrivals):
        now = time.perf_counter() - t0
        if now < arr:
            time.sleep(arr - now)
        start = time.perf_counter() - t0
        r = eng.generate(eng.pad_prompt(prompt[None]), max_new)
        ttfts.append(start + r.ttft_s - arr)    # queueing delay + prefill
        generated += max_new
    wall = time.perf_counter() - t0
    return generated / wall, np.asarray(ttfts), wall


def _prefix_reuse(eng, cfg, *, smoke: bool, seed: int, mesh_label: str):
    """Serve a shared-system-prompt trace twice over one warm pool: pass 1
    prefills cold, pass 2 admits every request via a prefix-cache hit."""
    chunk = cfg.quoka.chunk_size
    sys_len = 6 * chunk if smoke else 12 * chunk
    sfx_len = chunk if smoke else 2 * chunk
    n_requests = 4 if smoke else 8
    max_new = 4 if smoke else 16
    rng = np.random.default_rng(seed + 1)
    sys_tok = rng.integers(3, cfg.vocab, (sys_len,)).astype(np.int32)
    prompts = [np.concatenate(
        [sys_tok, rng.integers(3, cfg.vocab, (sfx_len,)).astype(np.int32)])
        for _ in range(n_requests)]
    kw = dict(block_size=chunk, max_decode_batch=n_requests,
              max_prefill_tokens=2 * chunk)

    # compile warmup on a DISTINCT trace (no prefix overlap with the
    # measured prompts, so the measured pass 1 is a true cold start)
    warm = [rng.integers(3, cfg.vocab, (sys_len + sfx_len,)).astype(np.int32)
            for _ in range(n_requests)]
    eng.serve(make_requests(warm, max_new), **kw)

    state = eng.make_serve_state(make_requests(prompts, max_new), **kw)
    cold = eng.serve(make_requests(prompts, max_new), state=state)
    hot = eng.serve(make_requests(prompts, max_new), state=state)
    assert eng.stats["cache_hits"] == n_requests, eng.stats
    ttft_cold = float(np.mean(list(cold.ttft_s.values())))
    ttft_hot = float(np.mean(list(hot.ttft_s.values())))
    speedup = ttft_cold / max(ttft_hot, 1e-9)
    emit("serving/prefix_reuse/cold", ttft_cold * 1e6,
         f"ttft={ttft_cold*1e3:.1f}ms", bench="serving_throughput",
         scenario="prefix_reuse", mode="cold", method=eng.method,
         mesh=mesh_label, granularity=cfg.quoka.granularity,
         reuse_interval=cfg.quoka.reuse_interval, fused=eng.fused,
         ttft_mean_s=ttft_cold, tokens_per_s=cold.tokens_per_s,
         n_requests=n_requests, prompt_len=sys_len + sfx_len)
    emit("serving/prefix_reuse/cached", ttft_hot * 1e6,
         f"speedup={speedup:.2f}x", bench="serving_throughput",
         scenario="prefix_reuse", mode="cached", method=eng.method,
         mesh=mesh_label, granularity=cfg.quoka.granularity,
         reuse_interval=cfg.quoka.reuse_interval, fused=eng.fused,
         ttft_mean_s=ttft_hot, tokens_per_s=hot.tokens_per_s,
         ttft_speedup=speedup, hit_rate=eng.stats["hit_rate"],
         evictions=eng.stats["evictions"],
         n_requests=n_requests, prompt_len=sys_len + sfx_len)
    print(f"# prefix_reuse: cold TTFT {ttft_cold*1e3:.1f} ms -> cached "
          f"{ttft_hot*1e3:.1f} ms = {speedup:.2f}x "
          f"(hit rate {eng.stats['hit_rate']:.2f})", flush=True)
    return speedup


def _host_offload(cfg, params, *, smoke: bool, seed: int, method: str,
                  mesh_label: str):
    """Hierarchical-pool scenario: a device pool sized BELOW the trace's
    working set (every finished request's prefix blocks are evicted before
    the re-send), served twice — once with the host tier on (eviction
    demotes, the re-send promotes: cache-hit TTFT) and once without it
    (eviction destroys, the re-send prefills cold).  The gated headline is
    the demoted-prefix-hit vs cold-prefill TTFT ratio — what turning
    eviction from cache loss into tiering is worth."""
    from repro.serving.pool import blocks_for_request
    chunk = cfg.quoka.chunk_size
    plen = 4 * chunk if smoke else 8 * chunk
    n_requests = 3 if smoke else 6
    max_new = 4 if smoke else 8
    rng = np.random.default_rng(seed + 2)
    prompts = [rng.integers(3, cfg.vocab, (plen,)).astype(np.int32)
               for _ in range(n_requests)]
    need = blocks_for_request(plen, max_new, chunk, chunk)
    # one request's reservation + one spare: serving request k+1 must evict
    # request k's just-registered prefix blocks
    kw = dict(block_size=chunk, num_blocks=need + 1, max_decode_batch=1,
              max_prefill_tokens=2 * chunk)
    eng = Engine(build_model(cfg), params, method=method)
    warm = [rng.integers(3, cfg.vocab, (plen,)).astype(np.int32)
            for _ in range(2)]
    ttft, stats = {}, {}
    for label, htb in (("cold", 0),
                       ("host_tier", (n_requests + 2) * (need + 1))):
        # compile on a throwaway state (distinct prompts, same geometry;
        # served twice so the demote AND promote paths are both traced),
        # then measure pass 2 of a fresh state: pass 1 fills + evicts, the
        # re-send hits the host tier (or prefills cold without one)
        wst = eng.make_serve_state(make_requests(prompts, max_new),
                                   host_tier_blocks=htb, **kw)
        eng.serve(make_requests(warm, max_new), state=wst)
        eng.serve(make_requests(warm, max_new), state=wst)
        st = eng.make_serve_state(make_requests(prompts, max_new),
                                  host_tier_blocks=htb, **kw)
        eng.serve(make_requests(prompts, max_new), state=st)
        res = eng.serve(make_requests(prompts, max_new), state=st)
        ttft[label] = float(np.mean(list(res.ttft_s.values())))
        stats[label] = dict(eng.stats)
    s = stats["host_tier"]
    assert s["demoted"] > 0 and s["promoted"] > 0, \
        f"host_offload scenario failed to exercise the tier: {s}"
    speedup = ttft["cold"] / max(ttft["host_tier"], 1e-9)
    emit("serving/host_offload/cold", ttft["cold"] * 1e6,
         f"ttft={ttft['cold']*1e3:.1f}ms", bench="serving_throughput",
         scenario="host_offload", mode="cold", method=method,
         mesh=mesh_label, granularity=cfg.quoka.granularity,
         reuse_interval=cfg.quoka.reuse_interval, fused=False,
         ttft_mean_s=ttft["cold"], n_requests=n_requests, prompt_len=plen,
         num_blocks=need + 1)
    emit("serving/host_offload/host_tier", ttft["host_tier"] * 1e6,
         f"speedup={speedup:.2f}x", bench="serving_throughput",
         scenario="host_offload", mode="host_tier", method=method,
         mesh=mesh_label, granularity=cfg.quoka.granularity,
         reuse_interval=cfg.quoka.reuse_interval, fused=False,
         ttft_mean_s=ttft["host_tier"], ttft_speedup=speedup,
         demoted=s["demoted"], promoted=s["promoted"],
         staged_used=s["staged_used"], host_evictions=s["host_evictions"],
         hit_rate=s["hit_rate"], n_requests=n_requests, prompt_len=plen,
         num_blocks=need + 1)
    print(f"# host_offload: cold TTFT {ttft['cold']*1e3:.1f} ms -> demoted-"
          f"prefix hit {ttft['host_tier']*1e3:.1f} ms = {speedup:.2f}x "
          f"({s['demoted']:.0f} demoted, {s['promoted']:.0f} promoted, "
          f"{s['staged_used']:.0f} staged)", flush=True)
    return speedup


def _multi_tenant_slo(cfg, params, *, smoke: bool, seed: int, method: str,
                      mesh_label: str):
    """SLO-policy scenario: a background tenant floods both decode slots
    with long-prefill, long-decode requests at t=0; an interactive tenant's
    short deadline-carrying prompts arrive while those decodes run.  Under
    FCFS the interactive requests wait for a background decode to finish;
    under SLOPolicy EDF admission preempts a background decode (block
    suspend/resume) and the interactive TTFT collapses.  The gated
    headline is the interactive-tenant p99 TTFT ratio (fcfs / slo)."""
    chunk = cfg.quoka.chunk_size
    n_bg, n_int = 2, (4 if smoke else 8)
    plen_bg = 4 * chunk if smoke else 8 * chunk
    mn_bg = 32 if smoke else 96            # long decode = wide preempt window
    mn_int = 2
    deadline = 0.02
    rng = np.random.default_rng(seed + 3)
    prompts = [rng.integers(3, cfg.vocab, (plen_bg,)).astype(np.int32)
               for _ in range(n_bg)] + \
              [rng.integers(3, cfg.vocab, (chunk,)).astype(np.int32)
               for _ in range(n_int)]
    arrivals = np.concatenate(
        [np.zeros(n_bg), 0.01 + 0.01 * np.arange(n_int)])

    def reqs():
        return make_requests(
            prompts, [mn_bg] * n_bg + [mn_int] * n_int, arrivals=arrivals,
            tenants=["background"] * n_bg + ["interactive"] * n_int,
            priorities=[0] * n_bg + [1] * n_int,
            ttft_deadlines=[None] * n_bg + [deadline] * n_int)

    kw = dict(block_size=chunk, max_decode_batch=2,
              max_prefill_tokens=2 * chunk)
    eng = Engine(build_model(cfg), params, method=method)
    int_rids = range(n_bg, n_bg + n_int)
    p99, res_by = {}, {}
    for pol in ("fcfs", "slo"):
        # per-policy states: a preempting policy compiles a wider
        # block-table geometry (resume worst case), so each arm warms and
        # measures its own geometry; the measured pass runs a fresh pool
        wst = eng.make_serve_state(reqs(), policy=pol, **kw)
        eng.serve(reqs(), state=wst)
        st = eng.make_serve_state(reqs(), policy=pol, **kw)
        res = eng.serve(reqs(), state=st)
        res_by[pol] = res
        p99[pol] = float(np.percentile(
            [res.ttft_s[rid] for rid in int_rids], 99))
    assert res_by["slo"].preemptions >= 1, \
        "multi_tenant_slo scenario failed to trigger a preemption"
    ratio = p99["fcfs"] / max(p99["slo"], 1e-9)
    for pol in ("fcfs", "slo"):
        res = res_by[pol]
        emit(f"serving/multi_tenant_slo/{pol}", p99[pol] * 1e6,
             f"int_p99={p99[pol]*1e3:.1f}ms", bench="serving_throughput",
             scenario="multi_tenant_slo", mode=pol, method=method,
             mesh=mesh_label, granularity=cfg.quoka.granularity,
             reuse_interval=cfg.quoka.reuse_interval, fused=False,
             interactive_ttft_p99_s=p99[pol],
             tokens_per_s=res.tokens_per_s,
             preemptions=res.preemptions, resumes=res.resumes,
             deadline_misses=res.deadline_misses,
             **(dict(interactive_ttft_p99_ratio=ratio)
                if pol == "slo" else {}),
             n_bg=n_bg, n_interactive=n_int, prompt_len=plen_bg)
    print(f"# multi_tenant_slo: interactive TTFT p99 fcfs "
          f"{p99['fcfs']*1e3:.1f} ms -> slo {p99['slo']*1e3:.1f} ms "
          f"= {ratio:.2f}x ({res_by['slo'].preemptions} preemptions, "
          f"{res_by['slo'].resumes} resumes)", flush=True)
    return ratio


def _granularity_scenario(cfg, params, prompts, arrivals, serve_kw, max_new,
                          *, mesh, mesh_label):
    """Serving TTFT, token-granular vs block-granular + cross-layer-reuse
    selection plans (block size == selection grid == B_CP, so a block plan
    is a sub-view of the paged pool's block table), plus the block plan
    re-served over the gather-free fused kernel route
    (``QuokaConfig.fused_select_attn``; kernels/selected_attention.py).
    Informational: the absolute TTFTs are runner-speed-bound; the gated
    baselines stay pinned to granularity=1."""
    chunk = cfg.quoka.chunk_size
    p50 = {}
    for label, quoka_kw in (("token_plan", dict(granularity=1,
                                                reuse_interval=1)),
                            ("block_plan", dict(granularity=chunk,
                                                reuse_interval=2)),
                            ("block_plan_fused",
                             dict(granularity=chunk, reuse_interval=2,
                                  fused_select_attn=True))):
        cfg_v = dataclasses.replace(
            cfg, quoka=dataclasses.replace(cfg.quoka, **quoka_kw))
        eng = Engine(build_model(cfg_v), params, method="quoka", mesh=mesh)
        eng.serve(make_requests(prompts, max_new), **serve_kw)   # compile
        res = eng.serve(make_requests(prompts, max_new, arrivals=arrivals),
                        **serve_kw)
        ttft = np.asarray(sorted(res.ttft_s.values()))
        p50[label] = float(np.percentile(ttft, 50))
        emit(f"serving/granularity/{label}", p50[label] * 1e6,
             f"ttft_p50={p50[label]*1e3:.1f}ms", bench="serving_throughput",
             scenario="granularity", mode=label, method="quoka",
             mesh=mesh_label, granularity=quoka_kw["granularity"],
             reuse_interval=quoka_kw["reuse_interval"],
             fused=quoka_kw.get("fused_select_attn", False),
             ttft_p50_s=p50[label], tokens_per_s=res.tokens_per_s,
             n_requests=len(prompts))
    ratio = p50["block_plan"] / max(p50["token_plan"], 1e-9)
    print(f"# granularity: token TTFT p50 {p50['token_plan']*1e3:.1f} ms vs "
          f"block+reuse {p50['block_plan']*1e3:.1f} ms "
          f"(block/token = {ratio:.2f})", flush=True)
    return ratio


def run(*, smoke: bool = False, method: str = "quoka", seed: int = 0,
        mesh_spec: str = None, metrics: bool = False):
    """``mesh_spec`` ('data=N,model=M') serves the trace on a device mesh
    (sharded params/caches/pool — the CI sharded-smoke job runs a 1x2 host
    mesh); every JSON record carries a ``mesh`` field so
    check_regression.py baselines (pinned to mesh="none") stay comparable
    when sharded and unsharded runs land in the same out/ directory.

    ``metrics`` serves the continuous trace with the obs/ registry attached:
    the continuous record gains selected-KV-fraction / occupancy fields
    (measuring the paper's fewer-KV claim live, not from a formula) and the
    JSONL / Prometheus / Chrome-trace dumps land in benchmarks/out/ next to
    the JSON records (the CI telemetry smoke step parses them)."""
    header("serving throughput (continuous batching vs one-at-a-time)")
    mark = json_mark()
    mesh = None
    mesh_label = "none"
    if mesh_spec:
        from repro.launch.mesh import mesh_from_spec
        mesh = mesh_from_spec(mesh_spec)
        mesh_label = mesh_spec
        print(f"# mesh {dict(mesh.shape)} over {mesh.size} devices",
              flush=True)
    cfg = get_config("qwen3-4b").smoke(n_layers=2, d_model=128, n_heads=4,
                                       n_kv_heads=2, d_ff=256, vocab=512)
    chunk = 16 if smoke else 32
    cfg = dataclasses.replace(
        cfg, quoka=dataclasses.replace(cfg.quoka, chunk_size=chunk,
                                       budget=2 * chunk, n_queries=8))
    # decode-heavy, overlapping-arrival trace: the regime continuous
    # batching targets (decode steps of running requests amortise across
    # the batch; at low rates or with prefill-dominated work both engines
    # are bound by the same prefill FLOPs and score roughly the same)
    n_requests = 4 if smoke else 12
    max_new = 6 if smoke else 48
    len_lo, len_hi = (24, 64) if smoke else (64, 192)
    rate = float("inf") if smoke else 50.0
    max_decode_batch = 4 if smoke else 8

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reg = None
    if metrics:
        from repro.obs import Registry
        reg = Registry()
    eng = Engine(model, params, method=method, mesh=mesh, registry=reg)
    rng = np.random.default_rng(seed)
    prompts, arrivals = _trace(rng, cfg.vocab, n_requests, len_lo, len_hi,
                               rate)
    serve_kw = dict(block_size=chunk, max_decode_batch=max_decode_batch,
                    max_prefill_tokens=2 * chunk)

    # warm both paths (compile), then measure.  The one-at-a-time engine
    # recompiles per padded prompt length — warm every distinct shape so the
    # comparison measures serving, not compilation (the continuous engine's
    # fixed step shapes need exactly one warmup trace).
    longest = max(prompts, key=len)
    eng.serve(make_requests([longest] * 2, max_new), **serve_kw)
    for n in sorted({-(-len(pr) // chunk) * chunk for pr in prompts}):
        eng.generate(eng.pad_prompt(prompts[0][:1].repeat(n)[None]),
                     max_new)

    if reg is not None:
        # the warmup serves above recorded into the registry; swap in a
        # fresh one (the compiled step fns read eng.registry at runtime)
        # so the exported telemetry covers only the measured trace
        from repro.obs import Registry
        reg = eng.registry = Registry()
    res = eng.serve(make_requests(prompts, max_new, arrivals=arrivals),
                    **serve_kw)
    obs_fields = {}
    if reg is not None:
        kv = reg.histograms.get("select/kv_fraction")
        if kv is not None and kv.count:
            obs_fields = dict(selected_kv_fraction_mean=kv.mean,
                              selected_kv_fraction_min=kv.min)
        occ = reg.gauges.get("pool/occupancy")
        if occ is not None:
            obs_fields["pool_occupancy"] = occ.value
    cont_ttft = np.asarray(sorted(res.ttft_s.values()))
    emit("serving/continuous/tokens_per_s", 1e6 / max(res.tokens_per_s, 1e-9),
         f"tps={res.tokens_per_s:.1f}", bench="serving_throughput",
         mode="continuous", method=method, mesh=mesh_label,
         granularity=cfg.quoka.granularity,
         reuse_interval=cfg.quoka.reuse_interval, fused=eng.fused,
         tokens_per_s=res.tokens_per_s,
         ttft_p50_s=float(np.percentile(cont_ttft, 50)),
         ttft_p99_s=float(np.percentile(cont_ttft, 99)),
         occupancy=res.occupancy, n_requests=n_requests, **obs_fields)

    seq_tps, seq_ttft, _ = _sequential(eng, prompts, arrivals, max_new)
    emit("serving/sequential/tokens_per_s", 1e6 / max(seq_tps, 1e-9),
         f"tps={seq_tps:.1f}", bench="serving_throughput",
         mode="sequential", method=method, mesh=mesh_label,
         granularity=cfg.quoka.granularity,
         reuse_interval=cfg.quoka.reuse_interval, fused=eng.fused,
         tokens_per_s=seq_tps,
         ttft_p50_s=float(np.percentile(seq_ttft, 50)),
         ttft_p99_s=float(np.percentile(seq_ttft, 99)),
         occupancy=1.0 / max_decode_batch, n_requests=n_requests)

    speedup = res.tokens_per_s / max(seq_tps, 1e-9)
    print(f"# continuous {res.tokens_per_s:.1f} tok/s "
          f"(occupancy {res.occupancy:.2f}, "
          f"TTFT p50 {np.percentile(cont_ttft, 50)*1e3:.0f} ms / "
          f"p99 {np.percentile(cont_ttft, 99)*1e3:.0f} ms)  vs  "
          f"sequential {seq_tps:.1f} tok/s  ->  {speedup:.2f}x", flush=True)

    prefix_speedup = _prefix_reuse(eng, cfg, smoke=smoke, seed=seed,
                                   mesh_label=mesh_label)
    host_speedup = None
    if mesh is None:          # host tier is single-device (pool.py raises)
        host_speedup = _host_offload(cfg, params, smoke=smoke, seed=seed,
                                     method=method, mesh_label=mesh_label)
    slo_ratio = _multi_tenant_slo(cfg, params, smoke=smoke, seed=seed,
                                  method=method, mesh_label=mesh_label)
    gran_ratio = None
    if method == "quoka":
        gran_ratio = _granularity_scenario(
            cfg, params, prompts, arrivals, serve_kw, max_new,
            mesh=mesh, mesh_label=mesh_label)
    write_json("serving_throughput", mark)
    if reg is not None:
        import os

        from repro.obs import export_all
        out_dir = os.path.join(os.path.dirname(__file__), "out")
        paths = export_all(reg, out_dir, prefix="serving_throughput")
        for kind, p in sorted(paths.items()):
            print(f"# telemetry {kind} -> {p}", flush=True)
    return {"continuous_vs_sequential": speedup,
            "prefix_ttft_speedup": prefix_speedup,
            "host_offload_ttft_speedup": host_speedup,
            "multi_tenant_slo_ttft_ratio": slo_ratio,
            "block_vs_token_ttft_p50": gran_ratio}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace for the fast CI tier")
    ap.add_argument("--method", default="quoka")
    ap.add_argument("--mesh", default=None, metavar="data=N,model=M",
                    help="serve on a device mesh (CPU: set XLA_FLAGS="
                         "--xla_force_host_platform_device_count first)")
    ap.add_argument("--metrics", action="store_true",
                    help="attach the obs/ telemetry registry to the "
                         "continuous engine and export JSONL / Prometheus "
                         "/ Chrome-trace dumps to benchmarks/out/")
    args = ap.parse_args()
    run(smoke=args.smoke, method=args.method, mesh_spec=args.mesh,
        metrics=args.metrics)


if __name__ == "__main__":
    main()
