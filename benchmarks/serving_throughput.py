"""Continuous-batching serving throughput: the paper's TTFT story measured
UNDER LOAD instead of in isolation.  A Poisson request trace is served (a)
by the continuous engine (paged KV pool + chunked-prefill/decode scheduler)
and (b) one request at a time (FCFS, per-request generate) — reporting
aggregate tokens/s, p50/p99 TTFT and mean decode-batch occupancy.

    PYTHONPATH=src python -m benchmarks.serving_throughput [--smoke]

Emits JSON to benchmarks/out/serving_throughput.json like attn_latency/ttft.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from benchmarks.common import emit, header, json_mark, write_json
from repro.configs import get_config
from repro.models.model import build_model
from repro.serving.engine import Engine
from repro.serving.request import make_requests


def _trace(rng, vocab, n_requests, len_lo, len_hi, rate):
    """Random-length prompts with Poisson arrivals (rate req/s; inf = all
    at t=0)."""
    lens = rng.integers(len_lo, len_hi + 1, n_requests)
    prompts = [rng.integers(3, vocab, (int(n),)).astype(np.int32)
               for n in lens]
    if np.isinf(rate):
        arrivals = np.zeros(n_requests)
    else:
        arrivals = np.cumsum(rng.exponential(1.0 / rate, n_requests))
    return prompts, arrivals


def _sequential(eng, prompts, arrivals, max_new):
    """FCFS, one request at a time; TTFT includes queueing delay."""
    t0 = time.perf_counter()
    ttfts, generated = [], 0
    for prompt, arr in zip(prompts, arrivals):
        now = time.perf_counter() - t0
        if now < arr:
            time.sleep(arr - now)
        start = time.perf_counter() - t0
        r = eng.generate(eng.pad_prompt(prompt[None]), max_new)
        ttfts.append(start + r.ttft_s - arr)    # queueing delay + prefill
        generated += max_new
    wall = time.perf_counter() - t0
    return generated / wall, np.asarray(ttfts), wall


def run(*, smoke: bool = False, method: str = "quoka", seed: int = 0):
    header("serving throughput (continuous batching vs one-at-a-time)")
    mark = json_mark()
    cfg = get_config("qwen3-4b").smoke(n_layers=2, d_model=128, n_heads=4,
                                       n_kv_heads=2, d_ff=256, vocab=512)
    chunk = 16 if smoke else 32
    cfg = dataclasses.replace(
        cfg, quoka=dataclasses.replace(cfg.quoka, chunk_size=chunk,
                                       budget=2 * chunk, n_queries=8))
    # decode-heavy, overlapping-arrival trace: the regime continuous
    # batching targets (decode steps of running requests amortise across
    # the batch; at low rates or with prefill-dominated work both engines
    # are bound by the same prefill FLOPs and score roughly the same)
    n_requests = 4 if smoke else 12
    max_new = 6 if smoke else 48
    len_lo, len_hi = (24, 64) if smoke else (64, 192)
    rate = float("inf") if smoke else 50.0
    max_decode_batch = 4 if smoke else 8

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, method=method)
    rng = np.random.default_rng(seed)
    prompts, arrivals = _trace(rng, cfg.vocab, n_requests, len_lo, len_hi,
                               rate)
    serve_kw = dict(block_size=chunk, max_decode_batch=max_decode_batch,
                    max_prefill_tokens=2 * chunk)

    # warm both paths (compile), then measure.  The one-at-a-time engine
    # recompiles per padded prompt length — warm every distinct shape so the
    # comparison measures serving, not compilation (the continuous engine's
    # fixed step shapes need exactly one warmup trace).
    longest = max(prompts, key=len)
    eng.serve(make_requests([longest] * 2, max_new), **serve_kw)
    for n in sorted({-(-len(pr) // chunk) * chunk for pr in prompts}):
        eng.generate(eng.pad_prompt(prompts[0][:1].repeat(n)[None]),
                     max_new)

    res = eng.serve(make_requests(prompts, max_new, arrivals=arrivals),
                    **serve_kw)
    cont_ttft = np.asarray(sorted(res.ttft_s.values()))
    emit("serving/continuous/tokens_per_s", 1e6 / max(res.tokens_per_s, 1e-9),
         f"tps={res.tokens_per_s:.1f}", bench="serving_throughput",
         mode="continuous", method=method, tokens_per_s=res.tokens_per_s,
         ttft_p50_s=float(np.percentile(cont_ttft, 50)),
         ttft_p99_s=float(np.percentile(cont_ttft, 99)),
         occupancy=res.occupancy, n_requests=n_requests)

    seq_tps, seq_ttft, _ = _sequential(eng, prompts, arrivals, max_new)
    emit("serving/sequential/tokens_per_s", 1e6 / max(seq_tps, 1e-9),
         f"tps={seq_tps:.1f}", bench="serving_throughput",
         mode="sequential", method=method, tokens_per_s=seq_tps,
         ttft_p50_s=float(np.percentile(seq_ttft, 50)),
         ttft_p99_s=float(np.percentile(seq_ttft, 99)),
         occupancy=1.0 / max_decode_batch, n_requests=n_requests)

    speedup = res.tokens_per_s / max(seq_tps, 1e-9)
    print(f"# continuous {res.tokens_per_s:.1f} tok/s "
          f"(occupancy {res.occupancy:.2f}, "
          f"TTFT p50 {np.percentile(cont_ttft, 50)*1e3:.0f} ms / "
          f"p99 {np.percentile(cont_ttft, 99)*1e3:.0f} ms)  vs  "
          f"sequential {seq_tps:.1f} tok/s  ->  {speedup:.2f}x", flush=True)
    write_json("serving_throughput", mark)
    return speedup


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace for the fast CI tier")
    ap.add_argument("--method", default="quoka")
    args = ap.parse_args()
    run(smoke=args.smoke, method=args.method)


if __name__ == "__main__":
    main()
