"""Pallas TPU kernels (validated on CPU via interpret=True).

  attention  -- blockwise online-softmax attention with QUOKA's
                [selected-prefix | causal-chunk] mask
  score      -- fused normalise + QbarK^T + max-over-queries scoring

Use through repro.kernels.ops (layout conversion + backend dispatch);
``resolve_backend`` picks "xla" | "pallas_interpret" | "pallas" from the
explicit argument, the REPRO_BACKEND env var, QuokaConfig.backend, or
hardware detection — in that order.
"""
from repro.kernels.ops import (attention, flash_attention,  # noqa: F401
                               quoka_score, resolve_backend, score)
