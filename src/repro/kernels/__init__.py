"""Pallas TPU kernels (validated on CPU via interpret=True).

  flash_attention  -- blockwise online-softmax attention with QUOKA's
                      [selected-prefix | causal-chunk] mask
  quoka_score      -- fused normalise + QbarK^T + max-over-queries scoring

Use through repro.kernels.ops (layout conversion + backend dispatch).
"""
from repro.kernels.ops import flash_attention, quoka_score  # noqa: F401
