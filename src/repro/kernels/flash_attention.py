"""Blockwise (flash) attention Pallas TPU kernel.

Target: TPU MXU — (block_q × d) @ (d × block_k) tiles streamed HBM→VMEM with
an online-softmax carry (m, l, acc) in VMEM scratch across the innermost
(arbitrary-order) grid dimension.  Validated on CPU with interpret=True
against kernels/ref.py::flash_attention_ref.

Mask semantics match QUOKA's post-selection attention: the first
``boundary`` keys are an unconditioned prefix (the selected KV budget),
the remaining keys are causal with respect to chunk-local indices:

    attend(i, j) iff k_valid[j] and (not causal or j < boundary
                                     or j - boundary <= i)

With boundary=0 this is plain causal attention (training); with
causal=False it is a dense cross-attention.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import autotune

try:  # TPU compiler params are optional on CPU/interpret
    from jax.experimental.pallas import tpu as pltpu
    _SCRATCH = lambda shape, dtype: pltpu.VMEM(shape, dtype)
    # renamed TPUCompilerParams -> CompilerParams across jax releases
    _COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or \
        getattr(pltpu, "TPUCompilerParams", None)
except Exception:  # pragma: no cover
    pltpu = None
    _SCRATCH = None
    _COMPILER_PARAMS = None

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, valid_ref, o_ref,
            m_ref, l_ref, acc_ref,
            *, scale: float, causal: bool, boundary: int,
            block_q: int, block_k: int, n_k: int):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qb = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, d)
    kb = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
    vb = v_ref[0, 0].astype(jnp.float32)                  # (bk, d)
    s = jax.lax.dot_general(qb, kb, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)

    iq = pl.program_id(2)
    qi = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kj = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = valid_ref[0, 0][None, :]
    if causal:
        mask = mask & ((kj < boundary) | ((kj - boundary) <= qi))

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    s = jnp.where(mask, s, NEG_INF)
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)  # explicit re-mask
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_prev + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, vb, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _done():
        l = l_ref[...]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = jnp.where(
            (l > 0)[:, None], acc_ref[...] / safe[:, None], 0.0
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "boundary", "scale", "block_q", "block_k",
                     "interpret"))
def flash_attention_bhtd(q, k, v, k_valid=None, *, causal: bool = True,
                         boundary: int = 0, scale: Optional[float] = None,
                         block_q: Optional[int] = None,
                         block_k: Optional[int] = None,
                         interpret: bool = True):
    """q: (b, h, tq, d); k, v: (b, h_kv, tk, d); k_valid: bool, either
    (b, tk) shared across heads or (b, h_kv, tk) per-KV-head (gathered
    selection budgets differ per KV head).  Shapes are padded to block
    multiples internally.

    ``block_q`` / ``block_k`` = None resolve through the autotuner's tuning
    table (kernels/autotune.py: exact-key table hit, else the deterministic
    128/128 defaults — the pre-autotuner constants), at trace time."""
    b, h, tq, d = q.shape
    h_kv, tk = k.shape[1], k.shape[2]
    g = h // h_kv
    scale = (d ** -0.5) if scale is None else scale

    tuned = None
    if block_q is None or block_k is None:
        tuned = autotune.lookup("flash_attention", t=tk, d=d, n_kv=h_kv,
                                budget=boundary, g=1)
        block_q = block_q or tuned["block_q"]
        block_k = block_k or tuned["block_k"]
    semantics = tuple(tuned["dimension_semantics"]) if tuned else \
        ("parallel", "parallel", "parallel", "arbitrary")
    block_q = min(block_q, max(8, 1 << (tq - 1).bit_length()))
    block_k = min(block_k, max(8, 1 << (tk - 1).bit_length()))
    pq = (-tq) % block_q
    pk = (-tk) % block_k
    pd = (-d) % 128 if not interpret else 0
    if k_valid is None:
        k_valid = jnp.ones((b, h_kv, tk), bool)
    elif k_valid.ndim == 2:
        k_valid = jnp.broadcast_to(k_valid[:, None, :], (b, h_kv, tk))
    if pq or pd:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, pd)))
    if pk or pd:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, pd)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, pd)))
    if pk:
        k_valid = jnp.pad(k_valid, ((0, 0), (0, 0), (0, pk)))
    tq_p, tk_p, d_p = tq + pq, tk + pk, d + pd
    n_k = tk_p // block_k
    grid = (b, h, tq_p // block_q, n_k)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, boundary=boundary,
        block_q=block_q, block_k=block_k, n_k=n_k)

    kwargs = {}
    if not interpret and _COMPILER_PARAMS is not None:  # pragma: no cover
        kwargs["compiler_params"] = _COMPILER_PARAMS(
            dimension_semantics=semantics)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d_p),
                         lambda bi, hi, iq, ik: (bi, hi, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d_p),
                         lambda bi, hi, iq, ik, g=g: (bi, hi // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d_p),
                         lambda bi, hi, iq, ik, g=g: (bi, hi // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k),
                         lambda bi, hi, iq, ik, g=g: (bi, hi // g, ik)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d_p),
                               lambda bi, hi, iq, ik: (bi, hi, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, tq_p, d_p), q.dtype),
        scratch_shapes=[
            _SCRATCH((block_q,), jnp.float32),
            _SCRATCH((block_q,), jnp.float32),
            _SCRATCH((block_q, d_p), jnp.float32),
        ],
        interpret=interpret,
        **kwargs,
    )(q, k, v, k_valid)
    return out[:, :, :tq, :d]
