"""Gather-free fused selected-attention Pallas kernel.

QUOKA's staged post-selection pipeline pays one full gather round-trip the
selection just saved: ``plan.materialize`` copies every selected KV pair
into a contiguous HBM buffer before ``flash_attention`` ever streams it.
This kernel collapses ``materialize + attention`` into ONE launch with zero
intermediate HBM traffic: the SelectionPlan's grid-granular block ids (and,
on the paged serving path, the pool's block table) arrive as
*scalar-prefetch* operands, and the BlockSpec index maps use them to stream
each selected ``(g, n_kv, d)`` KV slab HBM->VMEM straight from its home
location in the unmaterialized cache.

Mask semantics are exactly ``flash_attention.py``'s
``[selected-prefix | causal-chunk]`` boundary contract, with per-token
validity re-derived IN-KERNEL the same way ``plan.materialize`` re-derives
it (block plans include boundary-straddling blocks whole):

  selected region   attend(i, j)  iff  pos[j] >= 0  and  pos[j] < start
                                  and  the tile's plan id is not -1
  chunk region      attend(i, j)  iff  pos[j] >= 0  and  0 <= j_loc < t
                                  and  j_loc <= i_loc   (chunk-local causal)

so a straddling block contributes its strictly-prior tokens through the
selected region while its suffix attends causally through the chunk region
— never both (the two regions partition on ``pos < start``).

Grid: ``(b, h, ceil(t/block_q), n_sel + n_chunk)`` with the innermost
("arbitrary") dimension carrying the online-softmax scratch (m, l, acc).
The K tile is ``bk = largest divisor of g <= block_k`` so every selected
tile lies inside one grid block; the chunk region walks ``bk``-aligned
cache tiles from ``start`` rounded down (misaligned chunk starts — ragged
harness chunks, decode steps — are handled by the ``j_loc`` bounds).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import autotune

try:  # TPU compiler params / grid specs are optional on CPU builds
    from jax.experimental.pallas import tpu as pltpu
    _SCRATCH = lambda shape, dtype: pltpu.VMEM(shape, dtype)
    _COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or \
        getattr(pltpu, "TPUCompilerParams", None)
except Exception:  # pragma: no cover
    pltpu = None
    _SCRATCH = None
    _COMPILER_PARAMS = None

NEG_INF = -1e30


def _softmax_step(ik, s, mask, vb, m_ref, l_ref, acc_ref):
    """One online-softmax accumulation over a (block_q, bk) score tile."""
    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    s = jnp.where(mask, s, NEG_INF)
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)  # explicit re-mask
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_prev + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, vb, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new


def _finalize(ik, n_steps, o_ref, m_ref, l_ref, acc_ref):
    """Divide-out on the last K step; fully-masked rows (l == 0) emit
    zeros, never NaN/Inf (same guard as flash_attention.py)."""
    @pl.when(ik == n_steps - 1)
    def _done():
        l = l_ref[...]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = jnp.where(
            (l > 0)[:, None], acc_ref[...] / safe[:, None], 0.0
        ).astype(o_ref.dtype)


def _masks(idx_ref, start_ref, pos, *, bi, hi, iq, ik, group, n_sel, r, nb,
           bk, block_q, t):
    """The [selected | chunk] mask for this (iq, ik) tile — the in-kernel
    twin of materialize's validity re-derivation.  ``pos`` is the (bk,)
    absolute key positions of the tile actually streamed in."""
    start = start_ref[bi]
    in_sel = ik < n_sel
    blk = idx_ref[bi, hi // group, jnp.minimum(ik // r, nb - 1)]
    sel_ok = (pos >= 0) & (pos < start) & (blk >= 0)            # (bk,)
    rows = jax.lax.broadcasted_iota(jnp.int32, (block_q, bk), 0)
    lanes = jax.lax.broadcasted_iota(jnp.int32, (block_q, bk), 1)
    i_loc = iq * block_q + rows
    j_loc = (ik - n_sel) * bk + lanes - start % bk
    chunk_ok = ((j_loc >= 0) & (j_loc < t) & (j_loc <= i_loc)
                & (pos >= 0)[None, :])
    return jnp.where(in_sel,
                     jnp.broadcast_to(sel_ok[None, :], (block_q, bk)),
                     chunk_ok)


def _kernel(idx_ref, start_ref, q_ref, k_ref, v_ref, pos_ref, o_ref,
            m_ref, l_ref, acc_ref, *, scale, group, n_sel, r, nb, bk,
            block_q, n_steps, t):
    bi, hi, iq, ik = (pl.program_id(i) for i in range(4))
    qb = q_ref[0, 0].astype(jnp.float32) * scale               # (bq, d)
    kb = k_ref[0, 0].astype(jnp.float32)                       # (bk, d)
    vb = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(qb, kb, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    mask = _masks(idx_ref, start_ref, pos_ref[0], bi=bi, hi=hi, iq=iq,
                  ik=ik, group=group, n_sel=n_sel, r=r, nb=nb, bk=bk,
                  block_q=block_q, t=t)
    _softmax_step(ik, s, mask, vb, m_ref, l_ref, acc_ref)
    _finalize(ik, n_steps, o_ref, m_ref, l_ref, acc_ref)


def _k_tile(bi, hi, ik, idx_ref, start_ref, *, group, n_sel, r, nb, bk,
            n_tiles):
    """Logical cache tile (units of bk tokens) streamed at K step ik.

    Selected region: the plan id drives the tile — plan padding (-1) clamps
    to tile 0 and is masked in-body.  Chunk region: bk-aligned walk from
    ``start`` rounded down; steps past the needed range clamp to the last
    cache tile (their lanes fail the ``j_loc < t`` bound, so the clamped
    DMA is never attended)."""
    blk = jnp.maximum(idx_ref[bi, hi // group, jnp.minimum(ik // r, nb - 1)],
                      0)
    sel_tile = blk * r + ik % r
    chunk_tile = jnp.minimum(start_ref[bi] // bk + (ik - n_sel), n_tiles - 1)
    return jnp.where(ik < n_sel, sel_tile, chunk_tile)


def _resolve_tiles(t, T, d, n_kv, g, nb, block_q, block_k,
                   kernel_name="selected_attention"):
    """Shared geometry resolution: autotune lookup when the caller didn't
    pin tile sizes, then clip to the problem shape."""
    tuned = None
    if block_q is None or block_k is None:
        tuned = autotune.lookup(kernel_name, t=T, d=d, n_kv=n_kv,
                                budget=nb * g, g=g)
        block_q = block_q or tuned["block_q"]
        block_k = block_k or tuned["block_k"]
    block_q = min(block_q, max(8, 1 << (t - 1).bit_length()))
    bk = min(block_k, g)
    while g % bk:                 # largest divisor of g <= block_k
        bk -= 1
    semantics = tuple(tuned["dimension_semantics"]) if tuned else \
        ("parallel", "parallel", "parallel", "arbitrary")
    return block_q, bk, semantics


def _compiler_kwargs(interpret, semantics):
    if not interpret and _COMPILER_PARAMS is not None:  # pragma: no cover
        return {"compiler_params":
                _COMPILER_PARAMS(dimension_semantics=semantics)}
    return {}


def _norm_inputs(q, idx, chunk_start, n_kv):
    b = q.shape[0]
    idx = idx.astype(jnp.int32)
    if idx.ndim == 2:             # block plans are shared across KV heads
        idx = jnp.broadcast_to(idx[:, None, :], (b, n_kv, idx.shape[1]))
    start = jnp.asarray(chunk_start, jnp.int32)
    if start.ndim == 0:
        start = jnp.broadcast_to(start[None], (b,))
    return idx, start


@functools.partial(
    jax.jit,
    static_argnames=("granularity", "scale", "block_q", "block_k",
                     "interpret"))
def selected_attention_bhtd(q, k, v, key_pos, block_idx, chunk_start, *,
                            granularity: int = 1,
                            scale: Optional[float] = None,
                            block_q: Optional[int] = None,
                            block_k: Optional[int] = None,
                            interpret: bool = True):
    """Fused selected attention against a LINEAR cache view.

    q: (b, h, t, d) chunk queries; k, v: (b, n_kv, T, d) unmaterialized
    cache; key_pos: (b, T) absolute positions (-1 = unwritten slot);
    block_idx: ``SelectionPlan.idx`` — (b, B//g) grid block ids at
    granularity g > 1 (shared across KV heads), or (b, n_kv, B) per-head
    token slots at g == 1 (each token is a 1-token block);
    chunk_start: () or (b,) — the chunk's first absolute position, i.e. the
    selected/causal boundary.  Returns (b, h, t, d).
    """
    b, h, t, d = q.shape
    n_kv, T = k.shape[1], k.shape[2]
    group = h // n_kv
    g = int(granularity)
    scale = (d ** -0.5) if scale is None else scale

    idx, start = _norm_inputs(q, block_idx, chunk_start, n_kv)
    nb = idx.shape[2]
    block_q, bk, semantics = _resolve_tiles(
        t, T, d, n_kv, g, nb, block_q, block_k)
    if T % bk:
        raise ValueError(f"cache length {T} must be a multiple of the K "
                         f"tile {bk} (granularity {g})")
    r = g // bk
    n_sel = nb * r
    n_tiles = T // bk
    # chunk walk: enough bk-aligned tiles to cover [start, start + t) for
    # any start alignment (one extra tile absorbs the worst misalignment)
    n_chunk = (t + 2 * bk - 2) // bk
    n_steps = n_sel + n_chunk

    pq = (-t) % block_q
    pd = (-d) % 128 if not interpret else 0
    if pq or pd:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, pd)))
    if pd:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, 0), (0, pd)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pd)))
    d_p = d + pd
    grid = (b, h, (t + pq) // block_q, n_steps)

    tile = functools.partial(_k_tile, group=group, n_sel=n_sel, r=r, nb=nb,
                             bk=bk, n_tiles=n_tiles)
    kernel = functools.partial(
        _kernel, scale=scale, group=group, n_sel=n_sel, r=r, nb=nb, bk=bk,
        block_q=block_q, n_steps=n_steps, t=t)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d_p),
                         lambda bi, hi, iq, ik, idx_ref, start_ref:
                         (bi, hi, iq, 0)),
            pl.BlockSpec((1, 1, bk, d_p),
                         lambda bi, hi, iq, ik, idx_ref, start_ref:
                         (bi, hi // group,
                          tile(bi, hi, ik, idx_ref, start_ref), 0)),
            pl.BlockSpec((1, 1, bk, d_p),
                         lambda bi, hi, iq, ik, idx_ref, start_ref:
                         (bi, hi // group,
                          tile(bi, hi, ik, idx_ref, start_ref), 0)),
            pl.BlockSpec((1, bk),
                         lambda bi, hi, iq, ik, idx_ref, start_ref:
                         (bi, tile(bi, hi, ik, idx_ref, start_ref))),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d_p),
                               lambda bi, hi, iq, ik, idx_ref, start_ref:
                               (bi, hi, iq, 0)),
        scratch_shapes=[
            _SCRATCH((block_q,), jnp.float32),
            _SCRATCH((block_q,), jnp.float32),
            _SCRATCH((block_q, d_p), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, t + pq, d_p), q.dtype),
        interpret=interpret,
        **_compiler_kwargs(interpret, semantics),
    )(idx, start, q, k, v, key_pos.astype(jnp.int32))
    return out[:, :, :t, :d]


# ---------------------------------------------------------------------------
# paged variant: attend THROUGH the pool's block table
# ---------------------------------------------------------------------------

def _paged_kernel(idx_ref, start_ref, table_ref, q_ref, k_ref, v_ref,
                  pos_ref, o_ref, m_ref, l_ref, acc_ref, *, scale, group,
                  n_sel, r, nb, bk, block_q, n_steps, t, tiles_per_block,
                  nb_table):
    bi, hi, iq, ik = (pl.program_id(i) for i in range(4))
    qb = q_ref[0, 0].astype(jnp.float32) * scale
    kb = k_ref[0, :, 0, :].astype(jnp.float32)                 # (bk, d)
    vb = v_ref[0, :, 0, :].astype(jnp.float32)
    s = jax.lax.dot_general(qb, kb, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    mask = _masks(idx_ref, start_ref, pos_ref[0], bi=bi, hi=hi, iq=iq,
                  ik=ik, group=group, n_sel=n_sel, r=r, nb=nb, bk=bk,
                  block_q=block_q, t=t)
    # the tile streamed in came through the block table: unmapped logical
    # blocks (table id -1) clamp to physical block 0 in the index map and
    # must be masked here (a recycled block may hold stale pos >= 0)
    lt = _k_tile(bi, hi, ik, idx_ref, start_ref, group=group, n_sel=n_sel,
                 r=r, nb=nb, bk=bk, n_tiles=nb_table * tiles_per_block)
    mapped = table_ref[bi, jnp.minimum(lt // tiles_per_block,
                                       nb_table - 1)] >= 0
    _softmax_step(ik, s, mask & mapped, vb, m_ref, l_ref, acc_ref)
    _finalize(ik, n_steps, o_ref, m_ref, l_ref, acc_ref)


@functools.partial(
    jax.jit,
    static_argnames=("granularity", "block_size", "scale", "block_q",
                     "block_k", "interpret"))
def selected_attention_paged(q, k_pool, v_pool, pos_pool, block_idx,
                             chunk_start, table, *, granularity: int,
                             block_size: int,
                             scale: Optional[float] = None,
                             block_q: Optional[int] = None,
                             block_k: Optional[int] = None,
                             interpret: bool = True):
    """Fused selected attention THROUGH a paged pool's block table — no
    per-request gather of the logical cache at all.

    q: (b, h, t, d); k_pool, v_pool: (N, block_size, n_kv, d) pool leaves
    (physical blocks); pos_pool: (N, block_size); table: (b, nb_logical)
    physical block id per logical block, -1 = unmapped; block_idx /
    chunk_start as in ``selected_attention_bhtd`` but on the LOGICAL grid
    (the logical cache is ``table`` order, length nb_logical * block_size).
    The index maps compose ``physical = table[logical]`` with the plan ids,
    so selected slabs stream straight from their home pool blocks.
    """
    b, h, t, d = q.shape
    n_kv = k_pool.shape[2]
    bs = int(block_size)
    nb_table = table.shape[1]
    T = nb_table * bs
    group = h // n_kv
    g = int(granularity)
    scale = (d ** -0.5) if scale is None else scale
    if bs % g:
        raise ValueError(f"pool block size {bs} must be a multiple of the "
                         f"selection granularity {g}")

    idx, start = _norm_inputs(q, block_idx, chunk_start, n_kv)
    nb = idx.shape[2]
    block_q, bk, semantics = _resolve_tiles(
        t, T, d, n_kv, g, nb, block_q, block_k)
    r = g // bk
    n_sel = nb * r
    tiles_per_block = bs // bk
    n_chunk = (t + 2 * bk - 2) // bk
    n_steps = n_sel + n_chunk

    pq = (-t) % block_q
    pd = (-d) % 128 if not interpret else 0
    if pq or pd:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, pd)))
    if pd:
        k_pool = jnp.pad(k_pool, ((0, 0), (0, 0), (0, 0), (0, pd)))
        v_pool = jnp.pad(v_pool, ((0, 0), (0, 0), (0, 0), (0, pd)))
    d_p = d + pd
    grid = (b, h, (t + pq) // block_q, n_steps)

    tile = functools.partial(_k_tile, group=group, n_sel=n_sel, r=r, nb=nb,
                             bk=bk, n_tiles=nb_table * tiles_per_block)

    def _phys(bi, hi, ik, idx_ref, start_ref, table_ref):
        """(physical block, within-block tile) of the logical tile —
        the block-table composition the staged path paid a gather for."""
        lt = tile(bi, hi, ik, idx_ref, start_ref)
        phys = jnp.maximum(
            table_ref[bi, jnp.minimum(lt // tiles_per_block, nb_table - 1)],
            0)
        return phys, lt % tiles_per_block

    def _kv_map(bi, hi, iq, ik, idx_ref, start_ref, table_ref):
        phys, within = _phys(bi, hi, ik, idx_ref, start_ref, table_ref)
        return (phys, within, hi // group, 0)

    def _pos_map(bi, hi, iq, ik, idx_ref, start_ref, table_ref):
        phys, within = _phys(bi, hi, ik, idx_ref, start_ref, table_ref)
        return (phys, within)

    kernel = functools.partial(
        _paged_kernel, scale=scale, group=group, n_sel=n_sel, r=r, nb=nb,
        bk=bk, block_q=block_q, n_steps=n_steps, t=t,
        tiles_per_block=tiles_per_block, nb_table=nb_table)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d_p),
                         lambda bi, hi, iq, ik, *refs: (bi, hi, iq, 0)),
            pl.BlockSpec((1, bk, 1, d_p), _kv_map),
            pl.BlockSpec((1, bk, 1, d_p), _kv_map),
            pl.BlockSpec((1, bk), _pos_map),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d_p),
                               lambda bi, hi, iq, ik, *refs:
                               (bi, hi, iq, 0)),
        scratch_shapes=[
            _SCRATCH((block_q,), jnp.float32),
            _SCRATCH((block_q,), jnp.float32),
            _SCRATCH((block_q, d_p), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, t + pq, d_p), q.dtype),
        interpret=interpret,
        **_compiler_kwargs(interpret, semantics),
    )(idx, start, table.astype(jnp.int32), q, k_pool, v_pool,
      pos_pool.astype(jnp.int32))
    return out[:, :, :t, :d]
