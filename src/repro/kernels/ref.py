"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

Layouts here are kernel-native (BHTD) — the ops.py wrappers convert from the
framework's BTHD activations.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        boundary: int = 0,
                        k_valid: Optional[jax.Array] = None,
                        scale: Optional[float] = None):
    """Oracle for the flash kernel.

    q: (b, h, tq, d); k, v: (b, h_kv, tk, d) with h % h_kv == 0.
    k_valid: bool (b, tk) shared across heads, or (b, h_kv, tk) per KV head.
    Mask semantics (matching QUOKA's [selected | chunk] layout):
      attend(i, j) iff (k_valid[b(, h_kv), j]) and (j < boundary  OR
                        not causal  OR  j - boundary <= i)
    i.e. the first `boundary` keys are an unconditioned prefix (the selected
    budget), the remainder is causal w.r.t. the chunk-local index.
    """
    b, h, tq, d = q.shape
    h_kv, tk = k.shape[1], k.shape[2]
    g = h // h_kv
    scale = (d ** -0.5) if scale is None else scale
    kr = jnp.repeat(k, g, axis=1)
    vr = jnp.repeat(v, g, axis=1)
    logits = jnp.einsum("bhtd,bhsd->bhts", q.astype(jnp.float32),
                        kr.astype(jnp.float32)) * scale
    i = jnp.arange(tq)[:, None]
    j = jnp.arange(tk)[None, :]
    m = jnp.ones((tq, tk), bool)
    if causal:
        m = (j < boundary) | ((j - boundary) <= i)
    mask = m[None, None]
    if k_valid is not None:
        if k_valid.ndim == 2:
            kv_mask = k_valid[:, None, None, :]
        else:                                   # (b, h_kv, tk) per KV head
            kv_mask = jnp.repeat(k_valid, g, axis=1)[:, :, None, :]
        mask = mask & kv_mask
    logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    # rows with every key masked produce uniform garbage; zero them like the
    # kernel does (all-masked rows have l == 0)
    any_valid = mask.any(-1, keepdims=True)
    p = jnp.where(any_valid, p, 0.0)
    return jnp.einsum("bhts,bhsd->bhtd", p, vr.astype(jnp.float32)
                      ).astype(q.dtype)


def quoka_score_ref(qbar, k, valid):
    """Oracle for the fused scoring kernel (Algorithm 1 lines 7-10).

    qbar: (b, n_kv, n_q, d) — pre-aggregated, ALREADY normalised queries;
    k:    (b, n_kv, t, d)  — raw keys (normalised inside);
    valid: (b, t) bool.
    Returns fp32 scores (b, n_kv, t): max over n_q of CosSim, NEG_INF invalid.
    """
    kf = k.astype(jnp.float32)
    kn = kf / (jnp.linalg.norm(kf, axis=-1, keepdims=True) + 1e-8)
    s = jnp.einsum("bknd,bktd->bknt", qbar.astype(jnp.float32), kn)
    s = s.max(axis=2)
    return jnp.where(valid[:, None, :], s, NEG_INF)
