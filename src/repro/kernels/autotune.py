"""Kernel-geometry autotuner with a committed JSON tuning table.

Pallas kernel throughput on real hardware is dominated by tile geometry:
``(block_q, block_k)`` set the VMEM working set and MXU utilisation, and
``dimension_semantics`` tells the Mosaic pipeliner which grid dimensions may
reorder ("parallel") versus which carry the online-softmax state
("arbitrary").  The right point differs per problem shape, so geometry is
resolved through a persistent lookup table instead of hard-coded defaults:

    key     (kernel, backend, t, d, n_kv, budget, g)
    params  {block_q, block_k, num_stages, dimension_semantics}

Resolution order (``lookup`` — the hot path, called at trace time by
``flash_attention.py`` / ``selected_attention.py`` whenever the caller does
not pin block sizes):

  1. the active tuning table (``REPRO_TUNING`` env var if set, else the
     committed ``kernels/tuning_table.json``) — an exact-key hit;
  2. deterministic defaults (``default_params``) — identical on every
     machine, so untuned geometries behave exactly like the pre-autotuner
     hard-coded constants.

``lookup`` NEVER searches.  ``autotune`` is the offline entry point: on a
table miss it times every candidate through a caller-supplied ``measure``
callable, persists the winner into the active table and returns it; on a
hit it returns the stored entry without re-searching (the round-trip
property tests/test_autotune.py asserts via the module counters).

Re-tuning on new hardware::

    REPRO_TUNING=/tmp/tuned.json \
        python -m repro.kernels.autotune --tune flash_attention \
            --t 1024 --d 64 --n-kv 4 --budget 896

then commit the merged file back to ``kernels/tuning_table.json``.  CI
lints the committed table's schema with ``--lint``.

Tables are loaded once per process and cached: jitted callers bake the
looked-up geometry into their traces, so a mid-process table edit must call
``invalidate_cache()`` (tests do) to become visible.
"""
from __future__ import annotations

import argparse
import json
import os
import threading
from typing import Callable, Dict, Iterable, List, Optional

SCHEMA_VERSION = 1
_ENV_VAR = "REPRO_TUNING"
DEFAULT_TABLE = os.path.join(os.path.dirname(__file__), "tuning_table.json")

KERNELS = ("flash_attention", "selected_attention")
KEY_FIELDS = ("backend", "t", "d", "n_kv", "budget", "g")
PARAM_FIELDS = ("block_q", "block_k", "num_stages", "dimension_semantics")
_SEMANTICS = ("parallel", "arbitrary")

# process-wide resolution counters — the autotuner round-trip test asserts
# "second call is a table hit with no re-search" directly on these
HITS = 0          # lookup/autotune answered from the table
MISSES = 0        # lookup fell through to deterministic defaults
SEARCHES = 0      # autotune ran a candidate search

_TABLES: Dict[str, Dict[str, dict]] = {}     # path -> {key_str: entry}
_LOCK = threading.Lock()


def table_path() -> str:
    """Active tuning-table path: ``REPRO_TUNING`` overrides the committed
    table (point it at a scratch file to tune without touching the repo)."""
    return os.environ.get(_ENV_VAR) or DEFAULT_TABLE


def _backend_name(backend: Optional[str]) -> str:
    if backend:
        return backend
    import jax
    return jax.default_backend()          # "cpu" | "tpu" | "gpu"


def _key_str(kernel: str, key: dict) -> str:
    return "|".join([kernel] + [f"{f}={key[f]}" for f in KEY_FIELDS])


def _load(path: str) -> Dict[str, dict]:
    with _LOCK:
        if path in _TABLES:
            return _TABLES[path]
        entries: Dict[str, dict] = {}
        if os.path.exists(path):
            with open(path) as f:
                doc = json.load(f)
            for e in doc.get("entries", []):
                entries[_key_str(e["kernel"], e["key"])] = e
        _TABLES[path] = entries
        return entries


def invalidate_cache() -> None:
    """Drop the in-process table cache (after editing a table on disk)."""
    with _LOCK:
        _TABLES.clear()


def default_params(kernel: str, key: dict) -> dict:
    """Deterministic fallback geometry — the pre-autotuner constants.

    Identical on every machine so an absent/partial table can never make a
    run irreproducible; the kernels additionally clip block sizes to the
    actual problem shape (small tests are unaffected by tuning)."""
    del kernel, key
    return {"block_q": 128, "block_k": 128, "num_stages": 2,
            "dimension_semantics": ["parallel", "parallel", "parallel",
                                    "arbitrary"]}


def lookup(kernel: str, *, t: int, d: int, n_kv: int, budget: int = 0,
           g: int = 1, backend: Optional[str] = None) -> dict:
    """Resolve tile geometry for one problem shape.  Never searches:
    exact-key table hit or deterministic defaults.  Runs at trace time
    (plain python on static shapes), so the result is baked into the jit
    cache of the calling kernel wrapper."""
    global HITS, MISSES
    key = {"backend": _backend_name(backend), "t": int(t), "d": int(d),
           "n_kv": int(n_kv), "budget": int(budget), "g": int(g)}
    entry = _load(table_path()).get(_key_str(kernel, key))
    if entry is not None:
        HITS += 1
        return dict(entry["params"])
    MISSES += 1
    return default_params(kernel, key)


def candidate_grid(kernel: str, key: dict) -> List[dict]:
    """Deterministic candidate set for a search.  ``block_k`` candidates
    below the selection granularity are kept — the selected-attention
    kernel clips its K tile to the largest divisor of ``g`` anyway."""
    cands = []
    for bq in (64, 128, 256):
        for bk in (64, 128, 256):
            if bq > max(8, key["t"]) * 2 or bk > max(8, key["t"]) * 2:
                continue
            cands.append({"block_q": bq, "block_k": bk, "num_stages": 2,
                          "dimension_semantics": ["parallel", "parallel",
                                                  "parallel", "arbitrary"]})
    return cands


def autotune(kernel: str, measure: Callable[[dict], float], *, t: int,
             d: int, n_kv: int, budget: int = 0, g: int = 1,
             backend: Optional[str] = None,
             candidates: Optional[Iterable[dict]] = None,
             persist: bool = True) -> dict:
    """Search-on-miss resolution.

    ``measure(params) -> seconds`` times one candidate (exceptions mark the
    candidate infeasible).  On a table hit the stored params are returned
    immediately — no re-search, no measurement.  On a miss the best
    candidate is persisted (``persist=True``) into the ACTIVE table path
    and the in-process cache, so the very next call is a hit.
    """
    global HITS, SEARCHES
    if kernel not in KERNELS:
        raise ValueError(f"unknown kernel {kernel!r}; expected {KERNELS}")
    key = {"backend": _backend_name(backend), "t": int(t), "d": int(d),
           "n_kv": int(n_kv), "budget": int(budget), "g": int(g)}
    path = table_path()
    ks = _key_str(kernel, key)
    entry = _load(path).get(ks)
    if entry is not None:
        HITS += 1
        return dict(entry["params"])

    SEARCHES += 1
    best, best_s, tried = None, float("inf"), 0
    for params in (candidates or candidate_grid(kernel, key)):
        try:
            s = float(measure(dict(params)))
        except Exception:
            continue                      # infeasible geometry on this shape
        tried += 1
        if s < best_s:
            best, best_s = dict(params), s
    if best is None:
        best, best_s = default_params(kernel, key), float("nan")
    entry = {"kernel": kernel, "key": key, "params": best,
             "us": round(best_s * 1e6, 1), "searched": tried,
             "schema_version": SCHEMA_VERSION}
    with _LOCK:
        _TABLES.setdefault(path, {})[ks] = entry
    if persist:
        _write(path)
    return dict(best)


def _write(path: str) -> None:
    entries = sorted(_load(path).values(),
                     key=lambda e: _key_str(e["kernel"], e["key"]))
    doc = {"schema_version": SCHEMA_VERSION, "entries": entries}
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")


def lint(path: Optional[str] = None) -> List[str]:
    """Schema-validate a tuning table; returns a list of problems (empty ==
    clean).  CI runs this over the committed table on every push."""
    path = path or table_path()
    errs: List[str] = []
    if not os.path.exists(path):
        return [f"{path}: missing"]
    try:
        with open(path) as f:
            doc = json.load(f)
    except Exception as e:          # noqa: BLE001 — report, don't crash
        return [f"{path}: unparseable JSON ({e})"]
    if doc.get("schema_version") != SCHEMA_VERSION:
        errs.append(f"schema_version != {SCHEMA_VERSION}")
    seen = set()
    for i, e in enumerate(doc.get("entries", [])):
        where = f"entries[{i}]"
        if e.get("kernel") not in KERNELS:
            errs.append(f"{where}: unknown kernel {e.get('kernel')!r}")
            continue
        key, params = e.get("key", {}), e.get("params", {})
        missing = [f for f in KEY_FIELDS if f not in key]
        if missing:
            errs.append(f"{where}: key missing {missing}")
            continue
        for f in ("t", "d", "n_kv", "budget", "g"):
            if not (isinstance(key[f], int) and key[f] >= 0):
                errs.append(f"{where}: key.{f} must be a non-negative int")
        ks = _key_str(e["kernel"], key)
        if ks in seen:
            errs.append(f"{where}: duplicate key {ks}")
        seen.add(ks)
        for f in ("block_q", "block_k", "num_stages"):
            v = params.get(f)
            if not (isinstance(v, int) and v >= 1):
                errs.append(f"{where}: params.{f} must be a positive int")
        ds = params.get("dimension_semantics")
        if (not isinstance(ds, list) or
                any(s not in _SEMANTICS for s in ds)):
            errs.append(f"{where}: params.dimension_semantics must be a "
                        f"list over {_SEMANTICS}")
    return errs


# ---------------------------------------------------------------------------
# CLI: --lint for CI, --tune for (re-)tuning on new hardware
# ---------------------------------------------------------------------------

def _tune_cli(args) -> None:
    import jax
    import jax.numpy as jnp

    interpret = jax.default_backend() != "tpu"
    key = jax.random.PRNGKey(0)
    b, h = 1, args.n_kv * 4

    def _measure_flash(params):
        from repro.kernels.flash_attention import flash_attention_bhtd
        q = jax.random.normal(key, (b, h, args.t, args.d), jnp.float32)
        k = jax.random.normal(key, (b, args.n_kv, args.t, args.d))
        v = jax.random.normal(key, (b, args.n_kv, args.t, args.d))
        return _time(lambda: flash_attention_bhtd(
            q, k, v, boundary=args.budget, block_q=params["block_q"],
            block_k=params["block_k"], interpret=interpret))

    def _measure_selected(params):
        from repro.kernels.selected_attention import selected_attention_bhtd
        g = max(1, args.g)
        nb = max(1, args.budget // g)
        tq = min(args.t, 128)
        q = jax.random.normal(key, (b, h, tq, args.d), jnp.float32)
        k = jax.random.normal(key, (b, args.n_kv, args.t, args.d))
        v = jax.random.normal(key, (b, args.n_kv, args.t, args.d))
        pos = jnp.arange(args.t, dtype=jnp.int32)[None]
        idx = jnp.arange(nb, dtype=jnp.int32)[None]
        return _time(lambda: selected_attention_bhtd(
            q, k, v, pos, idx, jnp.int32(args.t - tq), granularity=g,
            block_q=params["block_q"], block_k=params["block_k"],
            interpret=interpret))

    def _time(fn, iters: int = 3) -> float:
        import time
        jax.block_until_ready(fn())        # compile/warm
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            ts.append(time.perf_counter() - t0)
        return sorted(ts)[len(ts) // 2]

    measure = {"flash_attention": _measure_flash,
               "selected_attention": _measure_selected}[args.tune]
    params = autotune(args.tune, measure, t=args.t, d=args.d,
                      n_kv=args.n_kv, budget=args.budget, g=args.g)
    print(f"tuned {args.tune} t={args.t} d={args.d} n_kv={args.n_kv} "
          f"budget={args.budget} g={args.g} -> {params}  "
          f"(table: {table_path()})")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--lint", action="store_true",
                    help="schema-validate the active tuning table")
    ap.add_argument("--show", action="store_true",
                    help="print the active table path + entries")
    ap.add_argument("--tune", choices=KERNELS,
                    help="search one key and persist the winner")
    ap.add_argument("--t", type=int, default=1024)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--n-kv", type=int, default=4)
    ap.add_argument("--budget", type=int, default=0)
    ap.add_argument("--g", type=int, default=1)
    args = ap.parse_args(argv)
    if args.lint:
        errs = lint()
        for e in errs:
            print(f"TUNING LINT: {e}")
        print(f"tuning table {table_path()}: "
              f"{'FAIL' if errs else 'OK'} ({len(_load(table_path()))} entries)")
        return 1 if errs else 0
    if args.show:
        print(table_path())
        print(json.dumps(sorted(_load(table_path()).values(),
                                key=lambda e: _key_str(e['kernel'],
                                                       e['key'])), indent=1))
        return 0
    if args.tune:
        _tune_cli(args)
        return 0
    ap.print_help()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
