"""Framework-facing kernel wrappers.

Dispatch policy: ``backend="auto"`` uses the Pallas kernels when a TPU is
present (compiled) and otherwise either the XLA reference (fast on CPU) or
the interpreted kernel (slow; used by the allclose test-suite via
``backend="pallas_interpret"``).

Activations use the framework BTHD layout; kernels are BHTD.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_bhtd
from repro.kernels.quoka_score import quoka_score_bhtd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(backend: str) -> str:
    if backend == "auto":
        return "pallas" if _on_tpu() else "xla"
    return backend


def flash_attention(q, k, v, k_valid=None, *, causal: bool = True,
                    boundary: int = 0, scale: Optional[float] = None,
                    backend: str = "auto"):
    """q: (b, tq, h, d); k, v: (b, tk, h_kv, d); k_valid: (b, tk) bool.
    Returns (b, tq, h, d)."""
    be = _resolve(backend)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if be == "xla":
        out = ref.flash_attention_ref(qt, kt, vt, causal=causal,
                                      boundary=boundary, k_valid=k_valid,
                                      scale=scale)
    else:
        out = flash_attention_bhtd(qt, kt, vt, k_valid, causal=causal,
                                   boundary=boundary, scale=scale,
                                   interpret=(be != "pallas"))
    return out.transpose(0, 2, 1, 3)


def quoka_score(qbar, k, valid, *, backend: str = "auto"):
    """qbar: (b, n_q, n_kv, d) normalised pre-aggregated queries (BTHD-ish);
    k: (b, t, n_kv, d) raw keys; valid: (b, t).
    Returns fp32 scores (b, n_kv, t)."""
    be = _resolve(backend)
    qt = qbar.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    if be == "xla":
        return ref.quoka_score_ref(qt, kt, valid)
    return quoka_score_bhtd(qt, kt, valid, interpret=(be != "pallas"))
