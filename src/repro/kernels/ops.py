"""Framework-facing kernel dispatch facade.

Every attention/scoring call in the system (chunked prefill, the serving
engine, the standalone accuracy harness, benchmarks) goes through the two
entry points here instead of hand-rolling masks + ``dense_attention``:

  * ``attention(q, k, v, k_valid, causal=, boundary=, backend=)`` —
    Algorithm 2's post-selection attention.  The first ``boundary`` keys are
    an unconditioned prefix (the gathered selection budget, all strictly
    before the chunk by construction), the remaining keys are causal with
    respect to chunk-local indices; ``k_valid`` masks budget padding and may
    be per-KV-head ((b, n_kv, tk)) since gathered budgets differ per head.
    ``boundary=0`` is plain causal attention; ``causal=False`` is dense
    cross attention.
  * ``score(qbar, k, valid, backend=)`` — Algorithm 1's fused scoring pass
    (normalise K -> Q̄Kᵀ -> max over queries -> validity mask).

Dispatch contract
-----------------
``backend`` is one of:

  "xla"              pure-jnp reference (kernels/ref.py) — fast on CPU,
                     compiles anywhere, the parity oracle.
  "pallas_interpret" the Pallas kernels run under ``interpret=True`` —
                     slow, exercises the exact kernel code path on any
                     backend (used by the parity/allclose suites).
  "pallas"           compiled Pallas TPU kernels.
  "auto" / None      resolve via `resolve_backend`.

``resolve_backend(backend, cfg)`` picks, in priority order:
  1. an explicit non-"auto" ``backend`` argument,
  2. the ``REPRO_BACKEND`` environment variable (global override),
  3. ``QuokaConfig.backend`` when not "auto",
  4. hardware auto-detection: "pallas" on TPU, else "xla".

All backends produce outputs equal within tolerance (enforced by
tests/test_backend_parity.py); layout conversion BTHD <-> BHTD happens here.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_bhtd
from repro.kernels.quoka_score import quoka_score_bhtd
from repro.kernels.selected_attention import (selected_attention_bhtd,
                                              selected_attention_paged)

BACKENDS = ("xla", "pallas_interpret", "pallas")
_ENV_VAR = "REPRO_BACKEND"


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_backend(backend: Optional[str] = None, cfg=None) -> str:
    """Resolve a backend name per the module-docstring priority order.

    ``cfg`` is a ``QuokaConfig`` (or anything with a ``backend`` attribute);
    the result is always a concrete member of ``BACKENDS``.
    """
    be = backend or "auto"
    if be == "auto":
        be = os.environ.get(_ENV_VAR, "auto")
    if be == "auto" and cfg is not None:
        be = getattr(cfg, "backend", "auto") or "auto"
    if be == "auto":
        be = "pallas" if _on_tpu() else "xla"
    if be not in BACKENDS:
        raise ValueError(f"unknown kernel backend {be!r}; "
                         f"expected one of {BACKENDS + ('auto',)}")
    return be


def attention(q, k, v, k_valid=None, *, causal: bool = True,
              boundary: int = 0, scale: Optional[float] = None,
              backend: Optional[str] = None, cfg=None):
    """Post-selection attention over a [selected budget | chunk] key layout.

    q: (b, tq, h, d); k, v: (b, tk, h_kv, d);
    k_valid: bool (b, tk) or (b, h_kv, tk) — False keys never attended.
    ``boundary`` (static) marks the selected-prefix length.
    Returns (b, tq, h, d).
    """
    be = resolve_backend(backend, cfg)
    # trace-time profiler marker: zero runtime cost, attributes the fused
    # ops to this region in jax.profiler / HLO metadata
    with jax.named_scope(f"ops_attention_{be}"):
        qt = q.transpose(0, 2, 1, 3)
        kt = k.transpose(0, 2, 1, 3)
        vt = v.transpose(0, 2, 1, 3)
        if be == "xla":
            out = ref.flash_attention_ref(qt, kt, vt, causal=causal,
                                          boundary=boundary, k_valid=k_valid,
                                          scale=scale)
        else:
            out = flash_attention_bhtd(qt, kt, vt, k_valid, causal=causal,
                                       boundary=boundary, scale=scale,
                                       interpret=(be != "pallas"))
        return out.transpose(0, 2, 1, 3)


def _selected_xla(q, k, v, key_pos, plan_idx, chunk_start, g, scale):
    """Parity oracle for the fused kernel: materialize the plan with
    ``take_along_axis`` (the staged path's gather, re-implemented locally —
    ops must not import core.plan), slice the chunk rows out of the cache,
    and run ``flash_attention_ref`` over the [budget | chunk] concat."""
    b, T, n_kv, d = k.shape
    t = q.shape[1]
    start = jnp.asarray(chunk_start, jnp.int32)
    if start.ndim == 0:
        start = jnp.broadcast_to(start[None], (b,))
    valid = (key_pos >= 0) & (key_pos < start[:, None])          # (b, T)
    idx = plan_idx.astype(jnp.int32)
    if g == 1:
        if idx.ndim == 2:
            idx = jnp.broadcast_to(idx[:, None, :], (b, n_kv, idx.shape[1]))
        safe = jnp.maximum(idx, 0)
        idx_t = safe.transpose(0, 2, 1)[..., None]               # (b,B,n_kv,1)
        k_sel = jnp.take_along_axis(k, idx_t, axis=1)
        v_sel = jnp.take_along_axis(v, idx_t, axis=1)
        shape = idx.shape[:2] + (T,)
        pos = jnp.take_along_axis(
            jnp.broadcast_to(key_pos[:, None, :], shape), safe, axis=2)
        ok = jnp.take_along_axis(
            jnp.broadcast_to(valid[:, None, :], shape), safe, axis=2)
        sel_valid = (idx >= 0) & ok & (pos >= 0)                 # (b,n_kv,B)
    else:
        if idx.ndim == 3:
            idx = idx[:, 0]               # block plans are head-shared
        nb = idx.shape[1]
        blocks = jnp.maximum(idx, 0)
        ib = blocks[:, :, None, None, None]
        k_sel = jnp.take_along_axis(
            k.reshape(b, T // g, g, n_kv, d), ib,
            axis=1).reshape(b, nb * g, n_kv, d)
        v_sel = jnp.take_along_axis(
            v.reshape(b, T // g, g, n_kv, d), ib,
            axis=1).reshape(b, nb * g, n_kv, d)
        ok_sel = jnp.take_along_axis(valid.reshape(b, T // g, g),
                                     blocks[:, :, None], axis=1)
        good = (ok_sel & (idx >= 0)[:, :, None]).reshape(b, nb * g)
        sel_valid = jnp.broadcast_to(good[:, None, :], (b, n_kv, nb * g))
    boundary = k_sel.shape[1]
    # chunk rows are CONTIGUOUS in the cache view (the chunk contract puts
    # them at [start, start + t), start <= T - t), so a clamped dynamic
    # slice replaces a per-row gather — the same access the fused kernel's
    # chunk-walk tiles make
    slc = jax.vmap(lambda x, s: jax.lax.dynamic_slice_in_dim(x, s, t, 0))
    k_chunk = slc(k, start)
    v_chunk = slc(v, start)
    cpos = slc(key_pos, start)                                   # (b, t)
    chunk_valid = jnp.broadcast_to((cpos >= 0)[:, None, :], (b, n_kv, t))
    k_valid = jnp.concatenate([sel_valid, chunk_valid], axis=-1)
    out = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3),
        jnp.concatenate([k_sel, k_chunk], axis=1).transpose(0, 2, 1, 3),
        jnp.concatenate([v_sel, v_chunk], axis=1).transpose(0, 2, 1, 3),
        causal=True, boundary=boundary, k_valid=k_valid, scale=scale)
    return out.transpose(0, 2, 1, 3)


def _linearize_pool(k_pool, v_pool, pos_pool, table):
    """Pool leaves -> per-request linear view (the xla oracle's stand-in
    for the index-map block-table composition).  Unmapped table slots read
    as empty blocks (pos == -1), mirroring serving/pool.py::gather."""
    b, nb_t = table.shape
    bs, n_kv, d = k_pool.shape[1:]
    safe = jnp.maximum(table, 0)
    k_lin = k_pool[safe].reshape(b, nb_t * bs, n_kv, d)
    v_lin = v_pool[safe].reshape(b, nb_t * bs, n_kv, d)
    pos_lin = jnp.where((table >= 0)[:, :, None], pos_pool[safe],
                        -1).reshape(b, nb_t * bs)
    return k_lin, v_lin, pos_lin


def selected_attention(q, k, v, key_pos, plan_idx, chunk_start, *,
                       granularity: int = 1, scale: Optional[float] = None,
                       backend: Optional[str] = None, cfg=None,
                       table=None, block_size: int = 0):
    """Gather-free fused twin of ``plan.materialize`` + ``attention``: one
    [selected-prefix | causal-chunk] attention straight off the
    ``SelectionPlan`` indices, with validity re-derived inside the kernel.

    q: (b, t, h, d) chunk queries (BTHD).
    Linear cache view (default): k, v (b, T, n_kv, d); key_pos (b, T).
    Paged pool view (``table`` given): k, v (N, block_size, n_kv, d) pool
      leaves, key_pos (N, block_size), table (b, nb_logical) with -1 =
      unmapped — the kernel attends THROUGH the block table.
    plan_idx: (b, B//g) block ids at granularity g > 1; (b, n_kv, B) token
      slots at g == 1.  chunk_start: () or (b,).
    Returns (b, t, h, d).

    Dispatch: "xla" is the parity oracle (take_along_axis materialize +
    flash_attention_ref — it DOES gather, by design); "pallas_interpret" /
    "pallas" run the scalar-prefetch Pallas kernel
    (kernels/selected_attention.py) with zero intermediate HBM traffic.
    """
    be = resolve_backend(backend, cfg)
    with jax.named_scope(f"ops_selected_attention_{be}"):
        if table is not None:
            if be == "xla":
                k, v, key_pos = _linearize_pool(k, v, key_pos, table)
                return _selected_xla(q, k, v, key_pos, plan_idx,
                                     chunk_start, granularity, scale)
            out = selected_attention_paged(
                q.transpose(0, 2, 1, 3), k, v, key_pos, plan_idx,
                chunk_start, table, granularity=granularity,
                block_size=block_size, scale=scale,
                interpret=(be != "pallas"))
            return out.transpose(0, 2, 1, 3)
        if be == "xla":
            return _selected_xla(q, k, v, key_pos, plan_idx, chunk_start,
                                 granularity, scale)
        out = selected_attention_bhtd(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), key_pos, plan_idx, chunk_start,
            granularity=granularity, scale=scale,
            interpret=(be != "pallas"))
        return out.transpose(0, 2, 1, 3)


def _score_xla(qbar, k, valid):
    """Production XLA twin of the fused scoring kernel, in BTHD layout.

    FUSED key normalisation (§Perf A1): scores are divided by per-key norms
    instead of materialising a normalised (fp32!) copy of the whole K cache
    — K is streamed once, in its storage dtype, by a single einsum; the
    self-dot runs bf16-reads/fp32-accumulate so no converted K copy is ever
    materialised (an astype(f32) here caused XLA to hoist a full-cache f32
    conversion across the prefill loop).  This is also the per-shard body
    of the T-local sharded scoring path (core/quoka.py), which is why it
    lives behind the facade: every shard of the mesh and the meshless
    fallback compute byte-identical score elements.
    """
    s = jnp.einsum("bnkd,btkd->bknt", qbar.astype(k.dtype), k,
                   preferred_element_type=jnp.float32)        # (b,n_kv,N_Q,t)
    sq = jnp.einsum("btkd,btkd->btk", k, k,
                    preferred_element_type=jnp.float32)
    inv = jax.lax.rsqrt(sq + 1e-16)                           # (b,t,n_kv)
    s = s * inv.transpose(0, 2, 1)[:, :, None, :]
    s = jnp.max(s, axis=2)
    return jnp.where(valid[:, None, :], s, ref.NEG_INF)


@functools.lru_cache(maxsize=32)
def score_projection(d: int, r: int, seed: int = 7) -> jax.Array:
    """Cached low-rank scoring projection (d, r).

    A fixed JL-style random projection stands in for the offline PCA of
    Loki / the `score_proj_dim` ablation (documented in selection.py).  The
    cache makes the projection a per-process constant: repeated chunks,
    decode steps and every layer of a stack reuse one array instead of
    re-deriving it per call (the old ``loki_scores`` rebuilt it on every
    chunk of every layer).

    The array is materialised under ``ensure_compile_time_eval`` so the
    cached value is always CONCRETE: the first call may happen inside a
    jit/scan trace (chunked prefill builds plans inside the scan body),
    and caching a tracer there would leak it into every later trace.
    """
    with jax.ensure_compile_time_eval():
        return jax.random.normal(jax.random.PRNGKey(seed), (d, r),
                                 jnp.float32) / jnp.sqrt(float(r))


def score(qbar, k, valid, *, backend: Optional[str] = None, cfg=None,
          proj: Optional[jax.Array] = None):
    """Fused QUOKA scoring (Algorithm 1 lines 7-10): cosine scores of
    pre-aggregated queries against normalised keys, max over the query axis.

    qbar: (b, n_q, n_kv, d) pre-aggregated NORMALISED queries (BTHD-ish);
    k: (b, t, n_kv, d) raw keys; valid: (b, t).
    Returns fp32 scores (b, n_kv, t) with NEG_INF on invalid slots.

    ``proj`` (d, r) optionally projects BOTH operands to a low-rank space
    before dispatch (`QuokaConfig.score_proj_dim`): the unchanged kernel
    then runs at head dim r, normalising the PROJECTED keys, so scores are
    cosines in the projected space.  Applying the projection here — above
    the backend split — keeps the xla and pallas branches twins for free.

    The keys may be any contiguous slice of a cache (scoring is local in
    the key axis), which is what the sharded T-local selection path relies
    on: each mesh shard scores only the keys it owns through this same
    entry point (projecting a slice == slicing the projected cache, so the
    low-rank mode composes with it exactly).
    """
    be = resolve_backend(backend, cfg)
    with jax.named_scope(f"ops_score_{be}"):
        if proj is not None:
            qbar = (qbar.astype(jnp.float32) @ proj)
            # project K in its storage dtype — an fp32 projected copy of the
            # cache would hoist a full-cache conversion (see _score_xla note)
            k = k @ proj.astype(k.dtype)
        if be == "xla":
            return _score_xla(qbar, k, valid)
        qt = qbar.transpose(0, 2, 1, 3)
        kt = k.transpose(0, 2, 1, 3)
        return quoka_score_bhtd(qt, kt, valid, interpret=(be != "pallas"))


# ---------------------------------------------------------------------------
# back-compat aliases (pre-facade names; "auto" keeps the old TPU-detection
# behaviour because resolve_backend falls through to hardware detection)
# ---------------------------------------------------------------------------

def flash_attention(q, k, v, k_valid=None, *, causal: bool = True,
                    boundary: int = 0, scale: Optional[float] = None,
                    backend: str = "auto"):
    return attention(q, k, v, k_valid, causal=causal, boundary=boundary,
                     scale=scale, backend=backend)


def quoka_score(qbar, k, valid, *, backend: str = "auto"):
    return score(qbar, k, valid, backend=backend)
