"""Fused QUOKA scoring Pallas TPU kernel (Algorithm 1 lines 7-10).

The scoring pass is memory-bound: it streams the entire K cache once while
Q̄ (N_Q × d per KV head, a few KB) stays resident in VMEM.  Fusing
(normalise K) -> (Q̄ Kᵀ) -> (max over N_Q) -> (validity mask) means the
(N_Q × T) score matrix never round-trips to HBM — the kernel reads each key
once and writes one fp32 score per key, ~the streaming lower bound.

Grid: (b, n_kv, T/block_t); block working set = block_t × d key tile.
Validated on CPU with interpret=True against kernels/ref.py::quoka_score_ref.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    # renamed TPUCompilerParams -> CompilerParams across jax releases
    _COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or \
        getattr(pltpu, "TPUCompilerParams", None)
except Exception:  # pragma: no cover
    pltpu = None
    _COMPILER_PARAMS = None

NEG_INF = -1e30


def _kernel(qbar_ref, k_ref, valid_ref, o_ref):
    qb = qbar_ref[0, 0].astype(jnp.float32)             # (n_q, d) resident
    kb = k_ref[0, 0].astype(jnp.float32)                # (bt, d) streamed
    inv = jax.lax.rsqrt(jnp.sum(kb * kb, axis=-1, keepdims=True) + 1e-16)
    kn = kb * inv                                       # normalise in-tile
    s = jax.lax.dot_general(qb, kn, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (n_q, bt)
    smax = s.max(axis=0)                                # max over queries
    o_ref[0, 0] = jnp.where(valid_ref[0], smax, NEG_INF)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def quoka_score_bhtd(qbar, k, valid, *, block_t: int = 512,
                     interpret: bool = True):
    """qbar: (b, n_kv, n_q, d) pre-aggregated normalised queries;
    k: (b, n_kv, t, d) raw keys; valid: (b, t) bool.
    Returns fp32 scores (b, n_kv, t)."""
    b, n_kv, n_q, d = qbar.shape
    t = k.shape[2]
    block_t = min(block_t, max(8, 1 << (t - 1).bit_length()))
    pt = (-t) % block_t
    pd = (-d) % 128 if not interpret else 0
    pq = (-n_q) % 8 if not interpret else 0
    if pt or pd:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pt), (0, pd)))
    if pt:
        valid = jnp.pad(valid, ((0, 0), (0, pt)))
    if pd:
        qbar = jnp.pad(qbar, ((0, 0), (0, 0), (0, 0), (0, pd)))  # zeros: dot-safe
    if pq:
        # pad the query axis with COPIES of existing rows — max-invariant (a
        # zero pad would bias the max toward 0 when all real scores are < 0)
        qbar = jnp.pad(qbar, ((0, 0), (0, 0), (0, pq), (0, 0)), mode="edge")
    t_p, d_p, q_p = t + pt, d + pd, n_q + pq
    grid = (b, n_kv, t_p // block_t)

    kwargs = {}
    if not interpret and _COMPILER_PARAMS is not None:  # pragma: no cover
        kwargs["compiler_params"] = _COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "parallel"))
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q_p, d_p), lambda bi, hi, it: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, block_t, d_p),
                         lambda bi, hi, it: (bi, hi, it, 0)),
            pl.BlockSpec((1, block_t), lambda bi, hi, it: (bi, it)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_t),
                               lambda bi, hi, it: (bi, hi, it)),
        out_shape=jax.ShapeDtypeStruct((b, n_kv, t_p), jnp.float32),
        interpret=interpret,
        **kwargs,
    )(qbar, k, valid)
    return out[:, :, :t]
