"""repro — production-grade JAX reproduction of QUOKA (query-oriented KV
selection for efficient LLM prefill) with multi-pod sharding, 10 assigned
architectures, Pallas TPU kernels, and a chunked-prefill serving engine."""
__version__ = "0.1.0"
