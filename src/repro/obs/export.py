"""Registry exporters: JSONL event log, Prometheus text format, and a
Chrome-trace-format span dump (openable at https://ui.perfetto.dev or
chrome://tracing).

All three render a `Registry` snapshot to plain text; none import the
serving stack, so they stay usable from benchmarks and offline analysis.
"""
from __future__ import annotations

import json
import math
import os
import re
from typing import Dict

from repro.obs.registry import Registry

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    """Sanitize a registry name ("serve/pool/occupancy") into a valid
    Prometheus metric name ("serve_pool_occupancy")."""
    n = _NAME_RE.sub("_", name)
    if n and n[0].isdigit():
        n = "_" + n
    return n


def _prom_value(v: float) -> str:
    if isinstance(v, float) and math.isnan(v):
        return "NaN"
    if v == float("inf"):
        return "+Inf"
    if v == float("-inf"):
        return "-Inf"
    return repr(float(v))


# ---------------------------------------------------------------------------
# JSONL
# ---------------------------------------------------------------------------

def jsonl_lines(reg: Registry) -> str:
    """Event log followed by one final ``snapshot`` record, one JSON object
    per line."""
    lines = [json.dumps(ev) for ev in reg.events]
    lines.append(json.dumps({"event": "snapshot", **reg.snapshot()}))
    return "\n".join(lines) + "\n"


def write_jsonl(reg: Registry, path: str) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        f.write(jsonl_lines(reg))
    return path


# ---------------------------------------------------------------------------
# Prometheus text exposition format
# ---------------------------------------------------------------------------

def prometheus_text(reg: Registry) -> str:
    """Render counters/gauges/histogram summaries in the Prometheus text
    exposition format (0.0.4).  Histograms are emitted as summaries:
    ``<name>{quantile="0.5|0.9|0.99"}``, ``<name>_sum``, ``<name>_count``.
    """
    out = []
    snap = reg.snapshot()
    for name, val in snap["counters"].items():
        pn = _prom_name(name)
        out.append(f"# TYPE {pn} counter")
        out.append(f"{pn} {_prom_value(val)}")
    for name, val in snap["gauges"].items():
        pn = _prom_name(name)
        out.append(f"# TYPE {pn} gauge")
        out.append(f"{pn} {_prom_value(val)}")
    for name, s in snap["histograms"].items():
        pn = _prom_name(name)
        out.append(f"# TYPE {pn} summary")
        for q, key in ((0.5, "p50"), (0.9, "p90"), (0.99, "p99")):
            out.append(f'{pn}{{quantile="{q}"}} {_prom_value(s[key])}')
        out.append(f"{pn}_sum {_prom_value(s['sum'])}")
        out.append(f"{pn}_count {int(s['count'])}")
    return "\n".join(out) + "\n"


def write_prometheus(reg: Registry, path: str) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        f.write(prometheus_text(reg))
    return path


# ---------------------------------------------------------------------------
# Chrome trace format (Perfetto / chrome://tracing)
# ---------------------------------------------------------------------------

def chrome_trace(reg: Registry) -> Dict:
    """Span dump in the Chrome trace event format: complete ("ph": "X")
    events with microsecond ``ts``/``dur`` relative to registry creation."""
    return {
        "traceEvents": [
            {"name": "process_name", "ph": "M", "pid": 1,
             "args": {"name": "repro.serve"}},
            *reg.trace_events,
        ],
        "displayTimeUnit": "ms",
    }


def write_chrome_trace(reg: Registry, path: str) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(chrome_trace(reg), f)
    return path


def export_all(reg: Registry, out_dir: str, prefix: str = "serve") -> Dict[str, str]:
    """Write all three formats under ``out_dir``; returns {kind: path}."""
    return {
        "jsonl": write_jsonl(reg, os.path.join(out_dir, f"{prefix}.metrics.jsonl")),
        "prometheus": write_prometheus(reg, os.path.join(out_dir, f"{prefix}.prom")),
        "trace": write_chrome_trace(reg, os.path.join(out_dir, f"{prefix}.trace.json")),
    }
