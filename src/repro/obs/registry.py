"""Serve-path metrics registry: counters, gauges, streaming histograms,
named scopes, step spans — with a no-op fast path when disabled.

Design constraints (ISSUE 7):

  * **Low overhead.**  Instruments are plain ``__slots__`` objects; a
    metric update is one attribute store / float add.  A DISABLED registry
    hands out shared null instruments whose methods do nothing, and its
    ``span`` is a reusable null context manager — callers keep one
    unconditional code path and pay ~a method call when telemetry is off.
    The serving engine goes further and guards whole instrumentation
    blocks on one cached ``registry.enabled`` bool, so the metrics-off
    serve path does no per-step telemetry work at all.
  * **Streaming quantiles.**  Histograms keep exact count/sum/min/max plus
    a bounded algorithm-R reservoir (deterministically seeded), so
    p50/p90/p99 are available over unbounded streams in O(reservoir)
    memory.  Quantiles are exact until the stream exceeds the reservoir.
  * **Spans double as trace events.**  ``span(name)`` times a host-side
    region into the histogram of the same name AND appends a Chrome/
    Perfetto ``ph: "X"`` trace event (exported by obs/export.py); a
    ``jax.profiler.TraceAnnotation`` wraps the region so the same spans
    appear on the TensorBoard/Perfetto timeline when the run executes
    under ``jax.profiler.trace``.

The registry is serve-loop-local (single-threaded, like the engine); it is
NOT thread-safe.  Everything here is host-side bookkeeping — in-jit
telemetry (per-layer selection stats) is produced as a pytree of device
scalars by core/plan.py and *fed into* this registry by the engine.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import numpy as np


class Counter:
    """Monotonic float counter."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    """Last-value gauge."""
    __slots__ = ("value",)

    def __init__(self):
        self.value = float("nan")

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Streaming distribution: exact count/sum/min/max + an algorithm-R
    reservoir for quantiles.  Deterministic (seeded per instrument) so test
    assertions and repeated runs are reproducible."""
    __slots__ = ("count", "sum", "min", "max", "_res", "_cap", "_rng")

    def __init__(self, reservoir: int = 1024, seed: int = 0):
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._cap = int(reservoir)
        self._res: List[float] = []
        self._rng = np.random.default_rng(seed)

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if len(self._res) < self._cap:
            self._res.append(v)
        else:
            # algorithm R: item i replaces a reservoir slot w.p. cap/i
            j = int(self._rng.integers(0, self.count))
            if j < self._cap:
                self._res[j] = v

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else float("nan")

    def quantile(self, q: float) -> float:
        if not self._res:
            return float("nan")
        return float(np.quantile(np.asarray(self._res), q))

    def summary(self) -> Dict[str, float]:
        return {"count": self.count, "sum": self.sum, "min": self.min,
                "max": self.max, "mean": self.mean,
                "p50": self.quantile(0.50), "p90": self.quantile(0.90),
                "p99": self.quantile(0.99)}


# ---------------------------------------------------------------------------
# no-op twins (shared singletons handed out by a disabled registry)
# ---------------------------------------------------------------------------

class _NullCounter(Counter):
    __slots__ = ()

    def inc(self, n: float = 1.0) -> None:
        pass


class _NullGauge(Gauge):
    __slots__ = ()

    def set(self, v: float) -> None:
        pass


class _NullHistogram(Histogram):
    __slots__ = ()

    def observe(self, v: float) -> None:
        pass


class _NullSpan:
    """Reusable null context manager (allocation-free enter/exit)."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_COUNTER = _NullCounter()
_NULL_GAUGE = _NullGauge()
_NULL_HISTOGRAM = _NullHistogram()
_NULL_SPAN = _NullSpan()


class _Span:
    """One timed host-side region: histogram sample + Chrome trace event +
    jax.profiler.TraceAnnotation (so ``jax.profiler.trace`` runs show the
    engine's step phases on the device timeline)."""
    __slots__ = ("_reg", "_name", "_args", "_t0", "_ann")

    def __init__(self, reg: "Registry", name: str, args: Optional[Dict]):
        self._reg = reg
        self._name = name
        self._args = args
        self._ann = None

    def __enter__(self):
        try:
            from jax.profiler import TraceAnnotation
            self._ann = TraceAnnotation(self._name)
            self._ann.__enter__()
        except Exception:          # profiler unavailable: spans still work
            self._ann = None
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dt = time.perf_counter() - self._t0
        if self._ann is not None:
            self._ann.__exit__(*exc)
        reg = self._reg
        reg.histogram(self._name).observe(dt)
        ev = {"name": self._name, "ph": "X", "pid": 1, "tid": 1,
              "ts": (self._t0 - reg.t0) * 1e6, "dur": dt * 1e6}
        if self._args:
            ev["args"] = self._args
        reg.trace_events.append(ev)
        return False


class Registry:
    """Named-scope metrics registry.

    ``counter/gauge/histogram(name)`` create-on-demand; ``scope(prefix)``
    returns a view that prefixes every name with ``prefix/``.  ``span``
    times a region (histogram + trace event); ``event`` appends a raw
    JSONL record.  A registry constructed with ``enabled=False`` is the
    no-op fast path: every instrument is a shared null object and nothing
    is ever recorded.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = bool(enabled)
        self.t0 = time.perf_counter()
        self.counters: Dict[str, Counter] = {}
        self.gauges: Dict[str, Gauge] = {}
        self.histograms: Dict[str, Histogram] = {}
        self.events: List[Dict] = []          # JSONL event log
        self.trace_events: List[Dict] = []    # Chrome/Perfetto trace events

    # ---- instruments -----------------------------------------------------
    def counter(self, name: str) -> Counter:
        if not self.enabled:
            return _NULL_COUNTER
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        if not self.enabled:
            return _NULL_GAUGE
        g = self.gauges.get(name)
        if g is None:
            g = self.gauges[name] = Gauge()
        return g

    def histogram(self, name: str) -> Histogram:
        if not self.enabled:
            return _NULL_HISTOGRAM
        h = self.histograms.get(name)
        if h is None:
            # per-instrument deterministic seed: stable across runs,
            # decorrelated across instruments
            h = self.histograms[name] = Histogram(
                seed=abs(hash(name)) % (2 ** 31))
        return h

    # ---- convenience -----------------------------------------------------
    def count(self, name: str, n: float = 1.0) -> None:
        self.counter(name).inc(n)

    def set(self, name: str, v: float) -> None:
        self.gauge(name).set(v)

    def observe(self, name: str, v: float) -> None:
        self.histogram(name).observe(v)

    def span(self, name: str, **args):
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, args or None)

    def event(self, kind: str, **fields) -> None:
        if not self.enabled:
            return
        self.events.append({"t_s": time.perf_counter() - self.t0,
                            "event": kind, **fields})

    def scope(self, prefix: str) -> "Scope":
        return Scope(self, prefix)

    # ---- views -----------------------------------------------------------
    def snapshot(self) -> Dict:
        """One plain-dict view of everything (exporters build on this)."""
        return {
            "counters": {k: c.value for k, c in sorted(self.counters.items())},
            "gauges": {k: g.value for k, g in sorted(self.gauges.items())},
            "histograms": {k: h.summary()
                           for k, h in sorted(self.histograms.items())},
        }

    def view(self, prefix: str) -> Dict[str, float]:
        """Flat counters+gauges under ``prefix/``, keyed by the suffix —
        the backward-compat shape of ``Engine.stats`` / ``ServeResult.prefix``."""
        pre = prefix.rstrip("/") + "/"
        out: Dict[str, float] = {}
        for k, c in self.counters.items():
            if k.startswith(pre):
                out[k[len(pre):]] = c.value
        for k, g in self.gauges.items():
            if k.startswith(pre):
                out[k[len(pre):]] = g.value
        return out


class Scope:
    """Name-prefixing view of a registry (``scope.counter("x")`` is
    ``reg.counter("prefix/x")``)."""
    __slots__ = ("_reg", "_prefix")

    def __init__(self, reg: Registry, prefix: str):
        self._reg = reg
        self._prefix = prefix.rstrip("/")

    @property
    def enabled(self) -> bool:
        return self._reg.enabled

    def _n(self, name: str) -> str:
        return f"{self._prefix}/{name}"

    def counter(self, name: str) -> Counter:
        return self._reg.counter(self._n(name))

    def gauge(self, name: str) -> Gauge:
        return self._reg.gauge(self._n(name))

    def histogram(self, name: str) -> Histogram:
        return self._reg.histogram(self._n(name))

    def count(self, name: str, n: float = 1.0) -> None:
        self._reg.count(self._n(name), n)

    def set(self, name: str, v: float) -> None:
        self._reg.set(self._n(name), v)

    def observe(self, name: str, v: float) -> None:
        self._reg.observe(self._n(name), v)

    def span(self, name: str, **args):
        return self._reg.span(self._n(name), **args)

    def event(self, kind: str, **fields) -> None:
        self._reg.event(kind, scope=self._prefix, **fields)

    def scope(self, prefix: str) -> "Scope":
        return Scope(self._reg, self._n(prefix))

    def view(self) -> Dict[str, float]:
        return self._reg.view(self._prefix)


#: the shared disabled registry — the default "metrics off" sink
NULL = Registry(enabled=False)
