"""Serve-path observability: metrics registry + exporters.

    from repro import obs
    reg = obs.Registry()                    # or obs.NULL when disabled
    reg.count("serve/steps")
    with reg.span("serve/step/prefill", tokens=256):
        ...
    obs.export_all(reg, "out/metrics")

See obs/registry.py for the instrument model and obs/export.py for the
JSONL / Prometheus / Chrome-trace formats.
"""
from repro.obs.registry import (  # noqa: F401
    NULL,
    Counter,
    Gauge,
    Histogram,
    Registry,
    Scope,
)
from repro.obs.export import (  # noqa: F401
    chrome_trace,
    export_all,
    jsonl_lines,
    prometheus_text,
    write_chrome_trace,
    write_jsonl,
    write_prometheus,
)
