"""Three-term roofline model from the compiled dry-run artifact.

    compute    = HLO_FLOPs   / (chips × peak_FLOP/s)
    memory     = HLO_bytes   / (chips × HBM_bw)
    collective = coll_bytes  / (chips × link_bw)

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (per the assignment).  FLOPs/bytes come from compiled.cost_analysis();
collective bytes from analysis/hlo.py over the compiled module text.

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) per training step;
for inference steps the factor is 2·N·D (forward only).  The ratio
MODEL_FLOPS / HLO_FLOPs flags remat/redundancy waste.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per chip (ICI)


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float
    useful_ratio: float      # MODEL_FLOPS / HLO_FLOPs
    bytes_per_chip: float    # peak memory from memory_analysis
    note: str = ""

    def as_dict(self) -> Dict:
        return asdict(self)


def analyse(arch: str, shape: str, mesh_name: str, chips: int,
            cost: Dict, coll: Dict, model_flops: float,
            bytes_per_chip: float = 0.0, note: str = "") -> Roofline:
    """``cost``/``coll`` are PER-DEVICE (the SPMD module is per-device;
    verified empirically — see hlo_cost.py).  ``model_flops`` is GLOBAL."""
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes", cost.get("bytes accessed", 0.0)))
    cb = float(coll.get("coll_total", coll.get("total", 0.0)))
    t_c = flops / PEAK_FLOPS
    t_m = byts / HBM_BW
    t_x = cb / LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bn = max(terms, key=terms.get)
    total_flops = flops * chips
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=byts, coll_bytes=cb,
        t_compute=t_c, t_memory=t_m, t_collective=t_x,
        bottleneck=bn, model_flops=model_flops,
        useful_ratio=(model_flops / total_flops) if total_flops else 0.0,
        bytes_per_chip=bytes_per_chip, note=note)


def model_flops(cfg, shape_kind: str, batch: int, seq: int,
                budget: Optional[int] = None) -> float:
    """Analytic 'useful' FLOPs for the step.

    train: 6·N_active·tokens.  prefill: 2·N_active·tokens (+ attention term).
    decode: 2·N_active·batch (one token each).
    Attention FLOPs are added explicitly since 6ND ignores them:
      train/full prefill: 2·2·L·H·hd·T²/2 per sequence (causal half);
      quoka prefill: T·(B_SA+B_CP) instead of T²/2;
      decode: T (or budget) per token.
    """
    n = cfg.active_param_count()
    toks = batch * seq
    hd = cfg.resolved_head_dim
    att_layers = sum(1 for pd, r in cfg.stacks() for k in pd * r
                     if k not in ("rwkv", "mamba"))
    if shape_kind == "train":
        base = 6.0 * n * toks
        att = 3 * 2 * 2 * att_layers * cfg.n_heads * hd * batch * seq * seq / 2
        return base + att
    if shape_kind == "prefill":
        base = 2.0 * n * toks
        bsa = budget or cfg.quoka.budget
        eff = min(seq, bsa + cfg.quoka.chunk_size)
        att = 2 * 2 * att_layers * cfg.n_heads * hd * batch * seq * eff
        return base + att
    if shape_kind == "decode":
        base = 2.0 * n * batch
        bsa = budget or cfg.quoka.budget
        eff = min(seq, bsa + 1)
        att = 2 * 2 * att_layers * cfg.n_heads * hd * batch * eff
        return base + att
    raise ValueError(shape_kind)
