"""Parse collective traffic out of lowered/compiled HLO text.

``cost_analysis`` has no collective term, so the roofline's third axis comes
from summing the result-shape bytes of every collective op in the module
(DESIGN/EXPERIMENTS: link-byte accounting per op):

    all-gather          result bytes           (each chip receives ~result)
    reduce-scatter      operand bytes ~ result * n  -> counted as result
    all-reduce          2x result bytes        (RS + AG decomposition)
    all-to-all          result bytes
    collective-permute  result bytes
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_FACTOR = {"all-reduce": 2.0}

# e.g.  %ag = bf16[2,128,4096]{2,1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([\d,]*)\][^=]*?\s(" +
    "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(")
_TUPLE_RE = re.compile(
    r"=\s*\((.*?)\)\s*(" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Total result bytes per collective kind (×2 for all-reduce).
    '-start' ops are counted, matching '-done' lines are skipped."""
    out: Dict[str, float] = defaultdict(float)
    for line in hlo_text.splitlines():
        if "-done(" in line:          # avoid double-counting async pairs
            continue
        hit = None
        for c in _COLLECTIVES:
            if c in line:
                hit = c
                break
        if hit is None:
            continue
        m = _OP_RE.search(line)
        if m:
            dtype, dims, kind = m.group(1), m.group(2), m.group(3)
            out[kind] += _shape_bytes(dtype, dims) * _FACTOR.get(kind, 1.0)
            continue
        m = _TUPLE_RE.search(line)
        if m:
            kind = m.group(2)
            for dt, dims in _SHAPE_RE.findall(m.group(1)):
                out[kind] += _shape_bytes(dt, dims) * _FACTOR.get(kind, 1.0)
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return dict(out)


# e.g.  ... gather(...), offset_dims={...}, ..., slice_sizes={1,1,16,4,64}
# the leading \s excludes "all-gather(" (hyphen, not whitespace, precedes it)
_GATHER_RE = re.compile(r"\sgather\([^\n]*?slice_sizes=\{([\d,]*)\}")


def gather_slice_sizes(hlo_text: str):
    """slice_sizes of every gather op in the module, in textual order.

    The selection-plan contiguity checks use this to assert that
    block-granular materialize lowers to gathers whose slices span a whole
    block extent (granularity tokens per slice) rather than per-token
    rows."""
    return [tuple(int(d) for d in m.group(1).split(",") if d)
            for m in _GATHER_RE.finditer(hlo_text)]


_OP_NAME_RE = re.compile(r'op_name="([^"]*)"')


def gathers_in_scope(hlo_text: str, scope: str):
    """slice_sizes of every gather whose ``metadata op_name`` contains
    ``scope`` (a ``jax.named_scope`` label survives into HLO metadata).

    The fused-selected-attention acceptance check uses this to assert that
    the serving step's lowering contains NO gather under the staged path's
    "plan_materialize" scope — i.e. the fused kernel really replaced the
    full-budget KV gather, not merely renamed it.  Callers should first
    assert the scope IS visible on a staged lowering of the same step, so a
    metadata-stripping compiler change fails loudly instead of passing
    vacuously."""
    out = []
    for line in hlo_text.splitlines():
        m = _GATHER_RE.search(line)
        if m is None:
            continue
        nm = _OP_NAME_RE.search(line)
        if nm is not None and scope in nm.group(1):
            out.append(tuple(int(d) for d in m.group(1).split(",") if d))
    return out


def while_trip_counts(hlo_text: str):
    """Best-effort trip counts of while loops (for FLOP sanity checks)."""
    return [int(m.group(1)) for m in
            re.finditer(r"trip_count[=:]\s*(\d+)", hlo_text)]
