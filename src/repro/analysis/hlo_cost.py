"""Trip-count-aware cost analysis over compiled (SPMD, per-device) HLO text.

XLA's ``compiled.cost_analysis()`` visits every computation ONCE — a
``lax.scan`` over 61 layers or 256 prefill chunks is counted as a single
iteration, which under-reports FLOPs/bytes by orders of magnitude (verified
empirically; see EXPERIMENTS.md §Dry-run methodology).  Compiled HLO, however,
annotates while ops with ``backend_config={"known_trip_count":{"n":...}}``.

This module parses the HLO module text into computations, builds the call
graph (while bodies/conds, fusions, conditionals), and accumulates:

  * flops            — 2·prod(result)·contract for every ``dot``;
                       counted inside fusion bodies too
  * bytes            — operands + result per instruction, EXCLUDING
                       instructions inside fusion bodies (the fusion op at
                       the call site already accounts for its HBM traffic)
                       — matching HloCostAnalysis "bytes accessed" semantics
  * collective bytes — per kind, ×2 for all-reduce, async pairs deduped

with while bodies multiplied by their known trip counts (nested loops
compose).  All numbers are PER DEVICE, since the SPMD module is per-device.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "s2": 1, "u2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_AR_FACTOR = 2.0

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*{\s*$")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations={([^}]*)}")
_TOAPPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":{"n":"(\d+)"')
_OPERANDS_RE = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape(text: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


@dataclass
class Instr:
    name: str
    rhs: str
    opcode: str
    result_bytes: int
    result_shape: Optional[Tuple[str, List[int]]]


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    shapes: Dict[str, Tuple[str, List[int]]] = field(default_factory=dict)
    sizes: Dict[str, int] = field(default_factory=dict)


_OPCODE_RE = re.compile(
    r"^(?:\([^)]*\)|[a-z][a-z0-9]*\[[\d,]*\](?:{[^}]*})?)\s+([\w\-]+)")


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = None
    for raw in text.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR.match(line)
        if hdr and line.endswith("{"):
            cur = Computation(name=hdr.group(1))
            comps[cur.name] = cur
            if line.lstrip().startswith("ENTRY"):
                entry = cur.name
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        om = _OPCODE_RE.match(rhs)
        opcode = om.group(1) if om else ""
        shape = _first_shape(rhs.split(" ", 1)[0] if rhs.startswith("(")
                             else rhs)
        # result bytes: everything before the opcode token is the shape part
        shape_part = rhs.split(opcode)[0] if opcode else rhs
        rb = _shape_bytes(shape_part)
        inst = Instr(name=name, rhs=rhs, opcode=opcode, result_bytes=rb,
                     result_shape=shape)
        cur.instrs.append(inst)
        cur.shapes[name] = shape
        cur.sizes[name] = rb
    return comps, entry


def _dot_flops(inst: Instr, comp: Computation) -> float:
    """2 * prod(result dims) * prod(contracted dims of lhs)."""
    ops = _operand_names(inst.rhs)
    if not ops or inst.result_shape is None:
        return 0.0
    lhs = comp.shapes.get(ops[0])
    if lhs is None:
        return 0.0
    cm = re.search(r"lhs_contracting_dims={([\d,]*)}", inst.rhs)
    contract = 1
    if cm:
        for d in cm.group(1).split(","):
            if d:
                contract *= lhs[1][int(d)] if int(d) < len(lhs[1]) else 1
    res = 1
    for d in inst.result_shape[1]:
        res *= d
    return 2.0 * res * contract


def _operand_names(rhs: str) -> List[str]:
    m = _OPERANDS_RE.search(rhs[rhs.find("("):] if "(" in rhs else "")
    if not m:
        return []
    names = []
    for tok in m.group(1).split(","):
        tok = tok.strip()
        # Compiled HLO writes TYPED operands ("f32[64,64]{1,0} %name");
        # hand-written HLO may use bare "%name".  Take the trailing
        # identifier; shape fragments produced by splitting tuple-shaped
        # operands on "," simply fail the lookup later (0 bytes), exactly
        # like before.
        tm = re.search(r"%([\w.\-]+)$", tok) or re.match(r"([\w.\-]+)$", tok)
        if tm:
            names.append(tm.group(1))
    return names


_NO_BYTES = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             # structural ops: cost comes from recursing into their bodies
             "while", "conditional", "call", "custom-call",
             # async halves are bookkeeping
             "all-gather-done", "all-reduce-done", "all-to-all-done",
             "collective-permute-done", "async-done"}


def _inst_bytes(inst: Instr, comp: Computation) -> float:
    """HBM-traffic estimate per instruction, mirroring HloCostAnalysis:
    in-place windowed updates count the WINDOW, not the aliased buffer
    (scan carries would otherwise over-count by the trip count)."""
    op = inst.opcode
    if op in _NO_BYTES:
        return 0.0
    ops = _operand_names(inst.rhs)
    if op == "dynamic-update-slice":
        upd = comp.sizes.get(ops[1], 0) if len(ops) > 1 else inst.result_bytes
        return 2.0 * upd
    if op == "dynamic-slice" or op == "gather":
        return 2.0 * inst.result_bytes
    if op == "scatter":
        upd = comp.sizes.get(ops[2], 0) if len(ops) > 2 else inst.result_bytes
        return 2.0 * upd
    if op == "fusion":
        return -1.0          # sentinel: resolved in ModuleCost._fusion_bytes
    operand_bytes = sum(comp.sizes.get(o, 0) for o in ops)
    return float(inst.result_bytes + operand_bytes)


class ModuleCost:
    def __init__(self, text: str):
        self.comps, self.entry = parse_module(text)
        self._fusion_bodies = set()
        for c in self.comps.values():
            for inst in c.instrs:
                if inst.opcode == "fusion":
                    m = _CALLS_RE.search(inst.rhs)
                    if m:
                        self._fusion_bodies.add(m.group(1))
        self._memo: Dict[str, Dict[str, float]] = {}

    # ------------------------------------------------------------------
    def _comp_cost(self, name: str, *, in_fusion: bool) -> Dict[str, float]:
        key = f"{name}|{in_fusion}"
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(name)
        acc: Dict[str, float] = defaultdict(float)
        if comp is None:
            return acc
        for inst in comp.instrs:
            op = inst.opcode
            if op == "dot":
                acc["flops"] += _dot_flops(inst, comp)
            if not in_fusion:
                nb = _inst_bytes(inst, comp)
                acc["bytes"] += self._fusion_bytes(inst, comp) if nb < 0 else nb
            # collectives (skip async -done halves)
            for coll in _COLLECTIVES:
                if op.startswith(coll) and not op.endswith("-done"):
                    factor = _AR_FACTOR if coll == "all-reduce" else 1.0
                    if not in_fusion:
                        acc[f"coll_{coll}"] += inst.result_bytes * factor
                    break
            # recurse
            if op == "while":
                bm, cm = _BODY_RE.search(inst.rhs), _COND_RE.search(inst.rhs)
                tm = _TRIP_RE.search(inst.rhs)
                trips = int(tm.group(1)) if tm else 1
                for sub in filter(None, [bm and bm.group(1),
                                         cm and cm.group(1)]):
                    subc = self._comp_cost(sub, in_fusion=in_fusion)
                    for k, v in subc.items():
                        acc[k] += v * trips
            elif op == "fusion":
                m = _CALLS_RE.search(inst.rhs)
                if m:
                    subc = self._comp_cost(m.group(1), in_fusion=True)
                    acc["flops"] += subc.get("flops", 0.0)
            elif op == "conditional":
                bm = _BRANCH_RE.search(inst.rhs)
                if bm:
                    # worst-case branch
                    best: Dict[str, float] = {}
                    for br in re.findall(r"%?([\w.\-]+)", bm.group(1)):
                        c = self._comp_cost(br, in_fusion=in_fusion)
                        if c.get("flops", 0) >= best.get("flops", 0):
                            best = c
                    for k, v in best.items():
                        acc[k] += v
            elif op in ("call", "custom-call", "async-start"):
                m = _CALLS_RE.search(inst.rhs) or _TOAPPLY_RE.search(inst.rhs)
                if m and m.group(1) not in self._fusion_bodies:
                    subc = self._comp_cost(m.group(1), in_fusion=in_fusion)
                    for k, v in subc.items():
                        acc[k] += v
        self._memo[key] = dict(acc)
        return self._memo[key]

    def _fusion_bytes(self, inst: Instr, comp: Computation) -> float:
        """Fusion traffic.  Fusions whose body slices/updates a window of a
        big operand (scan xs/carry access patterns) count the WINDOW; plain
        elementwise/reduce fusions count operands + result."""
        m = _CALLS_RE.search(inst.rhs)
        body = self.comps.get(m.group(1)) if m else None
        if body is not None:
            windowed = [bi for bi in body.instrs
                        if bi.opcode in ("dynamic-slice",
                                         "dynamic-update-slice", "gather",
                                         "scatter")]
            if windowed:
                # a fusion rooted in a dynamic-update-slice is aliased with
                # its operand buffer by XLA buffer assignment — the result is
                # updated IN PLACE, so only the windows count, not the result
                def _elems(shape):
                    n = 1
                    for dd in (shape[1] if shape else []):
                        n *= dd
                    return n
                res_elems = _elems(inst.result_shape)
                root_is_dus = any(
                    bi.opcode == "dynamic-update-slice"
                    and _elems(bi.result_shape) == res_elems
                    for bi in body.instrs)
                extra = 0.0 if root_is_dus else inst.result_bytes
                return (sum(_inst_bytes(bi, body) for bi in windowed)
                        + extra)
        ops = _operand_names(inst.rhs)
        return float(inst.result_bytes
                     + sum(comp.sizes.get(o, 0) for o in ops))

    def totals(self) -> Dict[str, float]:
        if self.entry is None:
            return {}
        acc = dict(self._comp_cost(self.entry, in_fusion=False))
        acc["coll_total"] = sum(v for k, v in acc.items()
                                if k.startswith("coll_"))
        return acc


def analyze_text(text: str) -> Dict[str, float]:
    return ModuleCost(text).totals()
