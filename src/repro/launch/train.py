"""Distributed training launcher.

Builds the production mesh (or a host mesh for CPU smoke), attaches the
FSDP+TP shardings from sharding/specs.py, and runs the training loop on
synthetic LM data.  On this CPU host use ``--host-mesh`` (optionally under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --smoke --steps 50 --batch 8 --seq 256
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.data.synthetic import lm_batches, needle_batches
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.model import build_model
from repro.sharding import ctx as shctx
from repro.sharding import specs as sh
from repro.training import checkpoint as ckpt
from repro.training import loop as train_loop
from repro.training import optimizer as opt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--data", choices=("lm", "needle"), default="lm")
    ap.add_argument("--host-mesh", default=None,
                    help="DATAxMODEL, e.g. 4x2 (CPU host devices)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--save", default=None, help="checkpoint path")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    cfg = dataclasses.replace(cfg, remat=not args.smoke)
    model = build_model(cfg)

    if args.host_mesh:
        d, m = (int(x) for x in args.host_mesh.split("x"))
        mesh = make_host_mesh(model=m, data=d)
    elif jax.device_count() >= 256:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    else:
        mesh = None   # single device

    ocfg = opt.OptimizerConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                               total_steps=args.steps)
    key = jax.random.PRNGKey(0)
    gen = (lm_batches(key, cfg.vocab, args.batch, args.seq) if args.data == "lm"
           else needle_batches(key, cfg.vocab, args.batch, args.seq | 1))

    if mesh is None:
        state, _ = train_loop.train(model, gen, ocfg=ocfg, steps=args.steps)
    else:
        shctx.set_policy(mesh, tuple(a for a in ("pod", "data")
                                     if a in mesh.axis_names))
        with mesh:
            state = train_loop.init_state(model, key)
            pspec = sh.param_specs(cfg, state.params, mesh)
            st_sh = sh.to_shardings(mesh, train_loop.TrainState(
                params=pspec, opt=opt.OptState(step=P(), mu=pspec, nu=pspec)))
            state = jax.device_put(state, st_sh)
            step_fn = jax.jit(train_loop.make_train_step(model, ocfg),
                              in_shardings=(st_sh, None), donate_argnums=0)
            for i in range(args.steps):
                batch = next(gen)
                state, metrics = step_fn(state, batch)
                if i % 10 == 0:
                    print(f"step {i:5d} loss {float(metrics['loss']):.4f} "
                          f"gnorm {float(metrics['grad_norm']):.3f}")
        shctx.clear_policy()

    if args.save:
        ckpt.save(args.save, state.params, {"arch": cfg.name,
                                            "steps": args.steps})
        print("saved", args.save)


if __name__ == "__main__":
    main()
