import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# NOTE: the two lines above MUST run before any other import (jax locks the
# device count at first init).  Do not move them; do not set this flag
# globally — smoke tests and benchmarks must see one real device.

import argparse                                                    # noqa: E402
import dataclasses                                                 # noqa: E402
import json                                                        # noqa: E402
import time                                                        # noqa: E402
from typing import Dict, Optional                                  # noqa: E402

import jax                                                         # noqa: E402
import jax.numpy as jnp                                            # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P        # noqa: E402

from repro.analysis import hlo_cost                                # noqa: E402
from repro.analysis import roofline as rl                          # noqa: E402
from repro.configs import ASSIGNED, get_config                     # noqa: E402
from repro.launch.mesh import make_production_mesh                 # noqa: E402
from repro.models.model import build_model                         # noqa: E402
from repro.sharding import specs as sh                             # noqa: E402
from repro.training import loop as train_loop                      # noqa: E402
from repro.training import optimizer as opt                        # noqa: E402

SHAPES = {
    "train_4k":    ("train",   4_096,   256),
    "prefill_32k": ("prefill", 32_768,  32),
    "decode_32k":  ("decode",  32_768,  128),
    "long_500k":   ("decode",  524_288, 1),
}

# long_500k needs sub-quadratic/state-bounded decode memory: SSM, hybrid and
# the sliding-window dense archs qualify (see DESIGN.md §Arch-applicability)
LONG_OK = {"gemma3-27b", "h2o-danube-3-4b", "rwkv6-1.6b", "zamba2-7b"}
# whisper is an enc-dec with a 30s window: decode shapes at 32k are lowered
# mechanically (self-attn cache 32k) but 500k is skipped.

RESULT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "experiments", "dryrun")


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg, shape_name: str) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    kind, seq, batch = SHAPES[shape_name]
    dt = cfg.compute_dtype
    out = {}
    text = seq
    if cfg.family == "vlm":
        text = seq - cfg.frontend.n_tokens
        out["patches"] = _sds((batch, cfg.frontend.n_tokens,
                               cfg.frontend.d_in), dt)
    if cfg.family == "audio":
        out["frames"] = _sds((batch, cfg.encoder.n_ctx, cfg.d_model), dt)
    out["tokens"] = _sds((batch, text), jnp.int32)
    return out


def _cast_float(tree, dtype):
    def c(s):
        if jnp.issubdtype(s.dtype, jnp.floating):
            return jax.ShapeDtypeStruct(s.shape, dtype)
        return s
    return jax.tree.map(c, tree)


def _mesh_name(multi_pod: bool) -> str:
    return "2x16x16" if multi_pod else "16x16"


def build_case(arch: str, shape_name: str, *, method: Optional[str] = None,
               cfg_override=None, chunkwise: bool = False):
    """Returns (fn, args_shape_structs, in_shardings_builder(mesh), meta)."""
    cfg = cfg_override or get_config(arch)
    if method:
        cfg = dataclasses.replace(
            cfg, quoka=dataclasses.replace(cfg.quoka, method=method))
    kind, seq, batch = SHAPES[shape_name]
    if kind == "train" and cfg_override is None:
        # activation checkpointing is the production baseline at this scale
        # (a 671B × 1M-token step does not fit HBM otherwise)
        cfg = dataclasses.replace(cfg, remat=True)
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    batch_s = input_specs(cfg, shape_name)

    if kind == "train":
        state_s = jax.eval_shape(
            lambda k: train_loop.init_state(model, k), key)
        state_s = _cast_float(state_s, cfg.compute_dtype)
        step = train_loop.make_train_step(model, opt.OptimizerConfig())
        args = (state_s, batch_s)

        def shardings(mesh):
            pspec = sh.param_specs(cfg, state_s.params, mesh)
            st = train_loop.TrainState(
                params=pspec,
                opt=opt.OptState(step=P(), mu=pspec, nu=pspec))
            return (sh.to_shardings(mesh, st),
                    sh.to_shardings(mesh, sh.batch_spec(cfg, batch_s, mesh)))
        return step, args, shardings, dict(cfg=cfg, model=model, kind=kind,
                                           seq=seq, batch=batch)

    # decode caches need seq+1 slots; pad capacity to a multiple of 16 so the
    # sequence axis stays shardable over `data` (a 524289-slot cache would
    # silently REPLICATE — found in §Perf iteration C2)
    cap = seq if kind == "prefill" else seq + 16
    cache_s = jax.eval_shape(lambda: model.init_cache(batch, cap))
    params_s = _cast_float(jax.eval_shape(model.init, key),
                           cfg.compute_dtype)

    if kind == "prefill":
        if chunkwise:
            # §Perf: steady-state per-chunk dispatch (production serving) —
            # one B_CP chunk with a donated cache; roofline terms are
            # multiplied by n_chunks by the caller for comparability
            bcp = cfg.quoka.chunk_size
            chunk_s = dict(batch_s)
            chunk_s["tokens"] = _sds((batch, bcp), jnp.int32)
            chunk_s.pop("patches", None)
            chunk_s.pop("frames", None)

            def step(p, b, pos0, c):
                return model.prefill_chunk(p, b, pos0, c)
            args = (params_s, chunk_s, _sds((), jnp.int32), cache_s)

            def shardings(mesh):
                return (sh.to_shardings(mesh, sh.param_specs(cfg, params_s,
                                                             mesh)),
                        sh.to_shardings(mesh, sh.batch_spec(cfg, chunk_s,
                                                            mesh)),
                        NamedSharding(mesh, P()),
                        sh.to_shardings(mesh, sh.cache_specs(cfg, cache_s,
                                                             mesh)))
            return step, args, shardings, dict(cfg=cfg, model=model,
                                               kind=kind, seq=seq,
                                               batch=batch, chunkwise=True)

        def step(p, b, c):
            return model.prefill(p, b, c)
        args = (params_s, batch_s, cache_s)

        def shardings(mesh):
            return (sh.to_shardings(mesh, sh.param_specs(cfg, params_s, mesh)),
                    sh.to_shardings(mesh, sh.batch_spec(cfg, batch_s, mesh)),
                    sh.to_shardings(mesh, sh.cache_specs(cfg, cache_s, mesh)))
    else:
        tok_s = _sds((batch,), jnp.int32)
        pos_s = _sds((), jnp.int32)

        def step(p, tok, pos, c):
            return model.decode_step(p, tok, pos, c)
        args = (params_s, tok_s, pos_s, cache_s)

        def shardings(mesh):
            bspec = P(sh.fsdp_axes(mesh)) if batch % 32 == 0 else P(None)
            return (sh.to_shardings(mesh, sh.param_specs(cfg, params_s, mesh)),
                    NamedSharding(mesh, bspec),
                    NamedSharding(mesh, P()),
                    sh.to_shardings(mesh, sh.cache_specs(cfg, cache_s, mesh)))
    return step, args, shardings, dict(cfg=cfg, model=model, kind=kind,
                                       seq=seq, batch=batch)


def dry_run(arch: str, shape_name: str, *, multi_pod: bool = False,
            method: Optional[str] = None, save: bool = True,
            verbose: bool = True, donate: bool = False,
            tag_suffix: str = "", chunkwise: bool = False) -> Dict:
    kind, seq, batch = SHAPES[shape_name]
    step, args, shardings, meta = build_case(arch, shape_name, method=method,
                                             chunkwise=chunkwise)
    cfg = meta["cfg"]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    # §Perf: donate the state/cache buffer so XLA updates it in place instead
    # of copying it every step (decode caches are tens of GB per chip)
    donate_argnums = ()
    if donate or chunkwise:
        donate_argnums = (0,) if kind == "train" else \
            ((3,) if (kind == "decode" or chunkwise) else (2,))

    from repro.sharding import ctx as shctx
    shctx.set_policy(mesh, tuple(a for a in ("pod", "data")
                                 if a in mesh.axis_names))
    t0 = time.time()
    try:
        with mesh:
            in_sh = shardings(mesh)
            jitted = jax.jit(step, in_shardings=in_sh,
                             donate_argnums=donate_argnums)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            t1 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t1
    finally:
        shctx.clear_policy()

    mem = compiled.memory_analysis()
    t2 = time.time()
    cost = hlo_cost.analyze_text(compiled.as_text())   # per-device, trip-aware
    t_analyse = time.time() - t2
    if chunkwise:                      # whole-prompt equivalent of the
        n_chunks = seq // cfg.quoka.chunk_size          # per-chunk step
        cost = {k: v * n_chunks for k, v in cost.items()}
    xla_cost = compiled.cost_analysis()
    if isinstance(xla_cost, list):
        xla_cost = xla_cost[0]
    mf = rl.model_flops(cfg, kind, batch, seq,
                        budget=None if (method or cfg.quoka.method) != "full"
                        else seq)
    bytes_per_chip = float(
        getattr(mem, "temp_size_in_bytes", 0)
        + getattr(mem, "argument_size_in_bytes", 0)
        + getattr(mem, "output_size_in_bytes", 0)
        - getattr(mem, "alias_size_in_bytes", 0))
    roof = rl.analyse(arch, shape_name, _mesh_name(multi_pod), chips,
                      cost, cost, mf, bytes_per_chip,
                      note=f"method={method or cfg.quoka.method}")
    res = roof.as_dict()
    res.update(t_lower_s=round(t_lower, 1), t_compile_s=round(t_compile, 1),
               t_analyse_s=round(t_analyse, 1),
               collectives={k: v for k, v in cost.items()
                            if k.startswith("coll_")},
               xla_flops_body_once=float(xla_cost.get("flops", 0.0)),
               mem_temp=float(getattr(mem, "temp_size_in_bytes", 0)),
               mem_args=float(getattr(mem, "argument_size_in_bytes", 0)),
               mem_out=float(getattr(mem, "output_size_in_bytes", 0)),
               mem_alias=float(getattr(mem, "alias_size_in_bytes", 0)))
    if verbose:
        print(f"[{arch} × {shape_name} × {_mesh_name(multi_pod)}] "
              f"compile {t_compile:.0f}s  flops/chip {res['hlo_flops']:.3g}  "
              f"bytes/chip {res['hlo_bytes']:.3g}  "
              f"coll/chip {res['coll_bytes']:.3g}  mem/chip {bytes_per_chip:.3g}  "
              f"useful={res['useful_ratio']:.2f}  "
              f"bottleneck={res['bottleneck']}"
              f"  t=({res['t_compute']*1e3:.2f},{res['t_memory']*1e3:.2f},"
              f"{res['t_collective']*1e3:.2f})ms")
    if save:
        os.makedirs(RESULT_DIR, exist_ok=True)
        tag = f"{arch}_{shape_name}_{_mesh_name(multi_pod)}"
        if method:
            tag += f"_{method}"
        if tag_suffix:
            tag += f"_{tag_suffix}"
        with open(os.path.join(RESULT_DIR, tag + ".json"), "w") as f:
            json.dump(res, f, indent=2, default=float)
    return res


def cases(include_long=True):
    for arch in ASSIGNED:
        for shape in SHAPES:
            if shape == "long_500k" and arch not in LONG_OK:
                continue
            if shape == "long_500k" and not include_long:
                continue
            yield arch, shape


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--method", default=None,
                    help="selection method override (e.g. full, quoka)")
    ap.add_argument("--donate", action="store_true",
                    help="donate state/cache buffers (§Perf)")
    ap.add_argument("--chunkwise", action="store_true",
                    help="lower the steady-state per-chunk prefill step "
                         "instead of the monolithic scan (§Perf)")
    ap.add_argument("--tag", default="", help="result filename suffix")
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    todo = []
    if args.all:
        todo = list(cases())
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape)]
    meshes = [False, True] if (args.both_meshes or args.multi_pod and args.all) \
        else [args.multi_pod]
    failures = []
    for arch, shape in todo:
        for mp in meshes:
            try:
                dry_run(arch, shape, multi_pod=mp, method=args.method,
                        donate=args.donate, tag_suffix=args.tag,
                        chunkwise=args.chunkwise)
            except Exception as e:  # noqa: BLE001
                failures.append((arch, shape, mp, repr(e)))
                print(f"FAIL [{arch} × {shape} × {_mesh_name(mp)}]: {e}")
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures: {failures}")
    print("dry-run: all combinations lowered and compiled OK")


if __name__ == "__main__":
    main()
