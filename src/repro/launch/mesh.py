"""Production mesh builders (functions, not module constants — importing
this module never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the `pod` axis is the
    slow DCI axis and carries only data-parallel gradient reduction."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1, data: int = 1):
    """Small mesh for CPU sharding tests (run under
    XLA_FLAGS=--xla_force_host_platform_device_count=N)."""
    return jax.make_mesh((data, model), ("data", "model"))


def parse_mesh_spec(spec: str):
    """'data=2,model=4' -> {"data": 2, "model": 4} (axis order preserved)."""
    out = {}
    for part in spec.split(","):
        name, _, size = part.partition("=")
        name, size = name.strip(), size.strip()
        if name not in ("pod", "data", "model") or not size.isdigit():
            raise ValueError(
                f"bad mesh spec {spec!r}: expected e.g. 'data=2,model=4' "
                f"with axes from (pod, data, model)")
        out[name] = int(size)
    return out


def mesh_from_spec(spec: str):
    """Build a mesh from a '--mesh data=N,model=M' flag value.

    The axis product must equal the visible device count (on CPU, set
    XLA_FLAGS=--xla_force_host_platform_device_count=N before the process
    starts — jax locks the device count at first init)."""
    axes = parse_mesh_spec(spec)
    n = 1
    for s in axes.values():
        n *= s
    have = len(jax.devices())
    if n != have:
        raise ValueError(
            f"mesh {spec!r} needs {n} devices but {have} are visible "
            f"(CPU runs: XLA_FLAGS=--xla_force_host_platform_device_count"
            f"={n})")
    return jax.make_mesh(tuple(axes.values()), tuple(axes))
