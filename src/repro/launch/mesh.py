"""Production mesh builders (functions, not module constants — importing
this module never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the `pod` axis is the
    slow DCI axis and carries only data-parallel gradient reduction."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1, data: int = 1):
    """Small mesh for CPU sharding tests (run under
    XLA_FLAGS=--xla_force_host_platform_device_count=N)."""
    return jax.make_mesh((data, model), ("data", "model"))
