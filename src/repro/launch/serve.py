"""Serving launcher: batched chunked-prefill + decode with QUOKA selection.

One-shot batch mode (TTFT / decode throughput, paper §4.6):

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \
        --prompt-len 1024 --max-new 32 --method quoka

Continuous-batching trace mode (paged KV pool + chunked-prefill/decode
scheduler + cross-request prefix caching; Poisson arrivals):

    PYTHONPATH=src python -m repro.launch.serve --smoke --continuous \
        --n-requests 16 --rate 8 --max-decode-batch 8

Prefix-cache-heavy traces — a shared system prompt, or multi-turn
conversations whose every turn re-sends the growing conversation:

    PYTHONPATH=src python -m repro.launch.serve --smoke --continuous \
        --trace shared --shared-len 256 --n-requests 8
    PYTHONPATH=src python -m repro.launch.serve --smoke --continuous \
        --trace multiturn --turns 4 --turn-gap 0.5 [--no-prefix-cache]

Sharded serving (params/caches/paged pool placed per sharding/specs.py,
QUOKA scoring T-local per shard; token-identical to single-device):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.serve --smoke --continuous \
        --mesh data=2,model=4

Loads a checkpoint if given (random init otherwise — latency numbers are
weight-independent) and reports TTFT / throughput / batch occupancy.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.selection import METHODS
from repro.models.model import build_model
from repro.serving.engine import Engine
from repro.serving.request import make_requests
from repro.serving.sampler import SamplerConfig
from repro.training import checkpoint as ckpt


def _build_trace(model, args, rng):
    """(prompts, arrivals, extras) for one of four trace shapes — extras is
    a dict of per-request ``make_requests`` kwargs (tenants / priorities /
    deadlines), empty for the single-tenant traces:

      poisson    independent random prompts, Poisson arrivals (--rate)
      shared     every prompt opens with one shared system prompt of
                 --shared-len tokens (the cross-request prefix-cache case)
      multiturn  conversations of --turns turns; each turn's prompt extends
                 the previous turn's prompt with fresh tokens, arriving
                 --turn-gap seconds apart (synthetic: extensions are random
                 tokens, not the model's own replies — latency is
                 weight-independent either way)
      multi_tenant  a background tenant floods long prompts at t=0 while an
                 interactive tenant's short prompts arrive at --rate
                 carrying --ttft-deadline; pair with --policy slo to see
                 EDF + preemption protect the interactive TTFT
    """
    vocab = model.cfg.vocab
    if args.trace == "multi_tenant":
        n_bg = max(1, args.n_requests // 4)
        n_int = max(1, args.n_requests - n_bg)
        bg = [rng.integers(3, vocab, (args.prompt_len,)).astype(np.int32)
              for _ in range(n_bg)]
        ilen = max(1, args.prompt_len // 8)
        inter = [rng.integers(3, vocab,
                              (int(rng.integers(max(1, ilen // 2),
                                                ilen + 1)),)).astype(np.int32)
                 for _ in range(n_int)]
        rate = args.rate if not np.isinf(args.rate) else 1000.0
        arrivals = np.concatenate(
            [np.zeros(n_bg), np.cumsum(rng.exponential(1.0 / rate, n_int))])
        extras = dict(
            tenants=["background"] * n_bg + ["interactive"] * n_int,
            priorities=[0] * n_bg + [1] * n_int,
            ttft_deadlines=[None] * n_bg + [args.ttft_deadline] * n_int)
        return bg + inter, arrivals, extras
    if args.trace in ("poisson", "shared"):
        arrivals = (np.zeros(args.n_requests) if np.isinf(args.rate)
                    else np.cumsum(rng.exponential(1.0 / args.rate,
                                                   args.n_requests)))
        if args.trace == "poisson":
            lens = rng.integers(max(1, args.prompt_len // 2),
                                args.prompt_len + 1, args.n_requests)
            prompts = [rng.integers(3, vocab, (int(n),)).astype(np.int32)
                       for n in lens]
        else:
            sys_tok = rng.integers(3, vocab,
                                   (args.shared_len,)).astype(np.int32)
            sfx = max(1, args.prompt_len - args.shared_len)
            prompts = [np.concatenate(
                [sys_tok, rng.integers(3, vocab,
                                       (int(rng.integers(1, sfx + 1)),)
                                       ).astype(np.int32)])
                for _ in range(args.n_requests)]
        return prompts, arrivals, {}
    assert args.trace == "multiturn"
    n_conv = max(1, args.n_requests // args.turns)
    ext = max(1, args.prompt_len // (2 * args.turns))
    prompts, arrivals = [], []
    for c in range(n_conv):
        start = (0.0 if np.isinf(args.rate)
                 else float(rng.exponential(args.turns / args.rate)) * c)
        cur = rng.integers(3, vocab,
                           (args.prompt_len // 2,)).astype(np.int32)
        for t in range(args.turns):
            if t:
                cur = np.concatenate(
                    [cur, rng.integers(3, vocab, (ext,)).astype(np.int32)])
            prompts.append(cur.copy())
            arrivals.append(start + t * args.turn_gap)
    return prompts, np.asarray(arrivals), {}


def _print_telemetry(reg):
    """Human-readable digest of the serve registry (full detail goes to the
    --trace-dir exports)."""
    snap = reg.snapshot()
    h, g = snap["histograms"], snap["gauges"]
    kv = h.get("select/kv_fraction")
    if kv and kv["count"]:
        print(f"{'telemetry':10s} selected-KV fraction mean {kv['mean']:.3f} "
              f"p50 {kv['p50']:.3f} min {kv['min']:.3f} "
              f"over {kv['count']} layer-steps "
              f"({100 * (1 - kv['mean']):.0f}% of KV skipped on average)")
    for nm in ("engine/prefill_step", "engine/decode_step",
               "sched/admission_wait_s"):
        s = h.get(nm)
        if s and s["count"]:
            print(f"{'telemetry':10s} {nm}: p50 {s['p50']*1e3:7.1f} ms  "
                  f"p99 {s['p99']*1e3:7.1f} ms  n {s['count']}")
    if "pool/occupancy" in g:
        print(f"{'telemetry':10s} pool occupancy {g['pool/occupancy']:.2f}  "
              f"cached blocks {g.get('pool/cached_blocks', 0):.0f}")


def _export_telemetry(reg, trace_dir, prefix):
    from repro.obs import export_all
    paths = export_all(reg, trace_dir, prefix=prefix)
    for kind, p in sorted(paths.items()):
        print(f"{'telemetry':10s} {kind} -> {p}")


def run_continuous(model, params, args, mesh=None):
    """Trace-driven continuous batching with prefix caching (see
    --trace / --no-prefix-cache)."""
    rng = np.random.default_rng(0)
    prompts, arrivals, extras = _build_trace(model, args, rng)
    reg = None
    if args.metrics or args.trace_dir:
        from repro.obs import Registry
        reg = Registry()
    eng = Engine(model, params, method=args.method, mesh=mesh,
                 sampler=SamplerConfig(temperature=args.temperature),
                 registry=reg)
    kw = dict(block_size=args.block_size, num_blocks=args.num_blocks,
              max_prefill_tokens=args.max_prefill_tokens,
              max_decode_batch=args.max_decode_batch,
              prefix_cache=not args.no_prefix_cache,
              host_tier_blocks=args.host_tier_blocks,
              prefetch_depth=args.prefetch_depth,
              policy=args.policy)
    # compile warmup with the REAL step geometry: the jit cache is keyed on
    # max_nb/num_blocks, which derive from the longest prompt and max_new
    longest = max(prompts, key=len)
    eng.serve(make_requests([longest] * 2, args.max_new), **kw)
    if reg is not None:
        # the step functions were compiled telemetry-on and read
        # ``eng.registry`` at runtime, so swapping in a fresh registry
        # drops the warmup trace's samples without recompiling
        from repro.obs import Registry
        reg = eng.registry = Registry()
    res = eng.serve(make_requests(prompts, args.max_new, arrivals=arrivals,
                                  **extras), **kw)
    ttft = np.asarray(sorted(res.ttft_s.values()))
    print(f"{args.method:10s} {res.generated} tokens / {res.wall_s:.2f} s "
          f"= {res.tokens_per_s:8.1f} tok/s   "
          f"TTFT p50 {np.percentile(ttft, 50)*1e3:7.1f} ms   "
          f"p99 {np.percentile(ttft, 99)*1e3:7.1f} ms   "
          f"occupancy {res.occupancy:.2f}   "
          f"steps {res.steps} ({res.prefill_steps} prefill / "
          f"{res.decode_steps} decode)")
    print(f"{'policy':10s} {res.policy}: {res.preemptions} preemptions, "
          f"{res.resumes} resumes, {res.deadline_misses} deadline misses")
    if extras:
        by_tenant = {}
        for r in make_requests(prompts, args.max_new, arrivals=arrivals,
                               **extras):
            if r.rid in res.ttft_s:
                by_tenant.setdefault(r.tenant, []).append(res.ttft_s[r.rid])
        for t, vals in sorted(by_tenant.items()):
            v = np.asarray(vals)
            print(f"{'tenant':10s} {t}: TTFT p50 "
                  f"{np.percentile(v, 50)*1e3:7.1f} ms   p99 "
                  f"{np.percentile(v, 99)*1e3:7.1f} ms   n {len(v)}")
    s = res.prefix
    if s:
        print(f"{'cache':10s} {s['cache_hits']:.0f}/{s['requests']:.0f} "
              f"requests hit, {s['hit_tokens']:.0f}/{s['prompt_tokens']:.0f} "
              f"prompt tokens served from cache ({100 * s['hit_rate']:.1f}%), "
              f"{s['evictions']:.0f} evictions, "
              f"{s['cow_copies']:.0f} COW copies")
        if "demoted" in s:
            print(f"{'host tier':10s} {s['demoted']:.0f} demoted, "
                  f"{s['promoted']:.0f} promoted "
                  f"({s['staged_used']:.0f} from prefetch staging), "
                  f"{s['host_evictions']:.0f} host evictions, "
                  f"{s['host_blocks']:.0f} blocks resident")
    if reg is not None:
        _print_telemetry(reg)
        if args.trace_dir:
            _export_telemetry(reg, args.trace_dir, f"serve_{args.method}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--method", default="quoka", choices=METHODS)
    ap.add_argument("--budget", type=int, default=None)
    ap.add_argument("--budget-ratio", type=float, default=None,
                    help="B_SA as a fraction of the prompt (paper Table 2)")
    ap.add_argument("--prompt-len", type=int, default=1024)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--compare-dense", action="store_true")
    # continuous-batching trace mode
    ap.add_argument("--continuous", action="store_true",
                    help="serve a Poisson trace with the paged-pool "
                         "scheduler instead of one synchronous batch")
    ap.add_argument("--n-requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=float("inf"),
                    help="Poisson arrival rate, requests/s (inf = all at 0)")
    ap.add_argument("--trace", default="poisson",
                    choices=("poisson", "shared", "multiturn",
                             "multi_tenant"),
                    help="trace shape: independent prompts, a shared "
                         "system prompt, multi-turn conversations (those "
                         "two exercise the prefix cache), or a background "
                         "tenant's long prompts vs an interactive tenant's "
                         "deadline-carrying short prompts (--policy slo)")
    ap.add_argument("--policy", default="fcfs", choices=("fcfs", "slo"),
                    help="scheduling policy: FCFS head-of-line (default) "
                         "or SLO-aware (EDF admission over TTFT deadlines, "
                         "per-tenant weighted fairness, preemption of "
                         "running decodes via block suspend/resume)")
    ap.add_argument("--ttft-deadline", type=float, default=0.5,
                    help="TTFT deadline (s) tagged onto the interactive "
                         "tenant's requests (--trace multi_tenant)")
    ap.add_argument("--shared-len", type=int, default=512,
                    help="shared system-prompt tokens (--trace shared)")
    ap.add_argument("--turns", type=int, default=4,
                    help="turns per conversation (--trace multiturn)")
    ap.add_argument("--turn-gap", type=float, default=0.5,
                    help="seconds between a conversation's turns "
                         "(--trace multiturn)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable cross-request KV prefix caching")
    ap.add_argument("--block-size", type=int, default=None,
                    help="KV pool block size (default: chunk_size)")
    ap.add_argument("--num-blocks", type=int, default=None,
                    help="KV pool size (default: fits max-decode-batch)")
    ap.add_argument("--host-tier-blocks", type=int, default=None,
                    help="hierarchical pool: host-memory tier capacity in "
                         "blocks (evicted prefix blocks demote there and "
                         "stay matchable; 0 disables, default: config). "
                         "Pair with an undersized --num-blocks to exercise "
                         "demotion")
    ap.add_argument("--prefetch-depth", type=int, default=None,
                    help="host-tier blocks staged (async H2D) per engine "
                         "step ahead of promotion, ranked by the QUOKA "
                         "selection-count oracle (default: config)")
    ap.add_argument("--max-prefill-tokens", type=int, default=None,
                    help="prompt tokens packed per engine step "
                         "(default: 4 * chunk_size)")
    ap.add_argument("--max-decode-batch", type=int, default=8)
    ap.add_argument("--metrics", action="store_true",
                    help="serve-path telemetry (obs/): step spans, "
                         "scheduler/pool counters and the in-jit per-layer "
                         "selected-KV fraction; prints a digest after the "
                         "run.  Off by default — the metrics-off serve "
                         "path is bit-identical to pre-telemetry builds")
    ap.add_argument("--trace-dir", default=None,
                    help="export telemetry (implies --metrics) to DIR: "
                         "JSONL event log, Prometheus text dump and a "
                         "Chrome/Perfetto trace of the engine's step spans; "
                         "one-shot mode instead captures a device timeline "
                         "there via jax.profiler.trace")
    ap.add_argument("--mesh", default=None, metavar="data=N,model=M",
                    help="serve sharded on a device mesh: params/caches/"
                         "paged pool placed per sharding/specs.py, QUOKA "
                         "scoring T-local per shard.  The axis product "
                         "must equal the visible device count (CPU: set "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N before launch)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke(n_layers=4, d_model=256, n_heads=8, n_kv_heads=2,
                        d_ff=512, vocab=2048)
    q = cfg.quoka
    if args.budget:
        q = dataclasses.replace(q, budget=args.budget)
    if args.budget_ratio:
        q = dataclasses.replace(q, budget_ratio=args.budget_ratio)
    cfg = dataclasses.replace(cfg, quoka=dataclasses.replace(
        q, chunk_size=min(q.chunk_size, args.prompt_len)))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt:
        params = ckpt.restore(args.ckpt, params)

    mesh = None
    if args.mesh:
        from repro.launch.mesh import mesh_from_spec
        mesh = mesh_from_spec(args.mesh)
        print(f"# mesh {dict(mesh.shape)} over {mesh.size} devices")

    if args.continuous:
        run_continuous(model, params, args, mesh=mesh)
        return

    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(3, cfg.vocab,
                                    (args.batch, args.prompt_len)), jnp.int32)
    methods = [args.method] + (["full"] if args.compare_dense else [])
    for m in methods:
        eng = Engine(model, params, method=m, mesh=mesh,
                     sampler=SamplerConfig(temperature=args.temperature))
        eng.generate({"tokens": toks}, 2)          # compile warmup
        if args.trace_dir:
            # one-shot mode: capture the device timeline (the named_scope
            # markers in kernels/ops.py + core/plan.py label the regions)
            with jax.profiler.trace(args.trace_dir):
                r = eng.generate({"tokens": toks}, args.max_new)
            print(f"# jax profiler trace -> {args.trace_dir}")
        else:
            r = eng.generate({"tokens": toks}, args.max_new)
        print(f"{m:18s} TTFT {r.ttft_s*1e3:9.1f} ms   "
              f"decode {r.decode_tps:8.1f} tok/s   "
              f"prompt {args.prompt_len} × {args.batch}")


if __name__ == "__main__":
    main()
