"""Serving launcher: batched chunked-prefill + decode with QUOKA selection.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \
        --prompt-len 1024 --max-new 32 --method quoka

Loads a checkpoint if given (random init otherwise — latency numbers are
weight-independent), pads/batches the prompts, and reports TTFT and decode
throughput for the chosen selection method vs dense.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.selection import METHODS
from repro.models.model import build_model
from repro.serving.engine import Engine
from repro.serving.sampler import SamplerConfig
from repro.training import checkpoint as ckpt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--method", default="quoka", choices=METHODS)
    ap.add_argument("--budget", type=int, default=None)
    ap.add_argument("--budget-ratio", type=float, default=None,
                    help="B_SA as a fraction of the prompt (paper Table 2)")
    ap.add_argument("--prompt-len", type=int, default=1024)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--compare-dense", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke(n_layers=4, d_model=256, n_heads=8, n_kv_heads=2,
                        d_ff=512, vocab=2048)
    q = cfg.quoka
    if args.budget:
        q = dataclasses.replace(q, budget=args.budget)
    if args.budget_ratio:
        q = dataclasses.replace(q, budget_ratio=args.budget_ratio)
    cfg = dataclasses.replace(cfg, quoka=dataclasses.replace(
        q, chunk_size=min(q.chunk_size, args.prompt_len)))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.ckpt:
        params = ckpt.restore(args.ckpt, params)

    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(3, cfg.vocab,
                                    (args.batch, args.prompt_len)), jnp.int32)
    methods = [args.method] + (["full"] if args.compare_dense else [])
    for m in methods:
        eng = Engine(model, params, method=m,
                     sampler=SamplerConfig(temperature=args.temperature))
        eng.generate({"tokens": toks}, 2)          # compile warmup
        r = eng.generate({"tokens": toks}, args.max_new)
        print(f"{m:18s} TTFT {r.ttft_s*1e3:9.1f} ms   "
              f"decode {r.decode_tps:8.1f} tok/s   "
              f"prompt {args.prompt_len} × {args.batch}")


if __name__ == "__main__":
    main()
