"""Sharding rules: param-path → PartitionSpec.

Scheme (DESIGN.md §6): tensor parallelism over ``model`` on heads / ffn /
expert / vocab axes; FSDP over ``(pod, data)`` on the embed axis (required to
fit deepseek-v3-671b); activations batch-sharded over ``(pod, data)``.
Long-context decode (batch=1) shards the KV-cache *sequence* axis over
``data`` instead.

Rules are matched on the '/'-joined param path suffix; stacked layers (extra
leading `repeats` axis from models/stack.py) are handled by right-aligning
the spec and padding with None.
"""
from __future__ import annotations

import re
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def fsdp_axes(mesh: Mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes if len(axes) > 1 else (axes[0] if axes else None)


def _rules(F):
    """(regex on path suffix, spec) — first match wins.  F = FSDP axes."""
    M = "model"
    return [
        # embeddings / readout (vocab over model, embed over FSDP)
        (r"embed/emb$",                 P(M, F)),
        (r"lm_head/w$",                 P(F, M)),
        # attention projections
        (r"(wq|wk|wv|wg|xq|xk|xv)/w$",  P(F, M)),
        (r"(wo|xo)/w$",                 P(M, F)),
        # MLA
        (r"wq_a/w$",                    P(F, None)),
        (r"wq_b/w$",                    P(None, M)),
        (r"wkv_a/w$",                   P(F, None)),
        (r"(wk_b|wv_b)$",               P(None, M, None)),
        # dense MLP
        (r"mlp/(gate|up)/w$",           P(F, M)),
        (r"mlp/down/w$",                P(M, F)),
        (r"shared/(gate|up)/w$",        P(F, M)),   # deepseek shared experts
        (r"shared/down/w$",             P(M, F)),
        # MoE (expert-parallel over model)
        (r"moe/router/w$",              P(F, None)),
        (r"moe/(gate|up)$",             P(M, F, None)),
        (r"moe/down$",                  P(M, None, F)),
        # RWKV6
        (r"tm/(wa)$",                   P(F, None)),
        (r"tm/(wb)$",                   P(None, M)),
        (r"tm/(w0)$",                   P(M)),
        (r"tm/u$",                      P(M, None)),
        (r"tm/mu$",                     P(None, None)),
        (r"cm/wk/w$",                   P(F, M)),
        (r"cm/wv/w$",                   P(M, F)),
        (r"cm/wr/w$",                   P(F, M)),
        # Mamba2 (x/z/dt head-aligned over model; B/C replicated)
        (r"(z_proj|x_proj)/w$",         P(F, M)),
        (r"bc_proj/w$",                 P(F, None)),
        (r"dt_proj/w$",                 P(F, M)),
        (r"conv_x_w$",                  P(None, M)),
        (r"conv_x_b$",                  P(M)),
        (r"conv_bc_(w|b)$",             P(None,)),
        (r"(a_log|d_skip|dt_bias)$",    P(M)),
        (r"mamba/norm/g$",              P(M)),
        (r"out_proj/w$",                P(M, F)),
        # VLM projector
        (r"proj/fc\d/w$",               P(F, None)),
        # MTP mixer
        (r"mtp/mix/w$",                 P(F, None)),
        # norms, biases, everything small: replicated
        (r".*",                         None),
    ]


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                    for p in path)


_HEAD_SENSITIVE = re.compile(r"(wq|wg|xq|wo|xo)/w$|wq_b/w$|(wk_b|wv_b)$")
_KV_SENSITIVE = re.compile(r"(wk|wv|xk|xv)/w$")


def param_specs(cfg: ModelConfig, params_tree, mesh: Mesh):
    """PartitionSpec tree matching `params_tree` (arrays or ShapeDtypeStructs).

    Head-count semantics: a fused (d, n_heads*head_dim) projection only
    shards over `model` when the HEAD count divides the axis — otherwise the
    split would cut through a head (whisper 12H, internvl 14H, granite kv=8
    on a 16-way model axis) and XLA would reshard every layer.
    """
    F = fsdp_axes(mesh)
    msize = mesh.shape.get("model", 1)
    head_ok = cfg.n_heads % msize == 0
    kv_ok = cfg.n_kv_heads % msize == 0
    rules = [(re.compile(pat), spec) for pat, spec in _rules(F)]

    def assign(path, leaf):
        s = _path_str(path)
        ndim = len(leaf.shape)
        drop_model = ((_KV_SENSITIVE.search(s) and not kv_ok)
                      or (_HEAD_SENSITIVE.search(s) and not head_ok))
        for pat, spec in rules:
            if pat.search(s):
                if drop_model and spec is not None:
                    spec = P(*[None if ax == "model" else ax
                               for ax in tuple(spec)])
                if spec is None:
                    return P()
                spec_t = tuple(spec)
                if len(spec_t) > ndim:          # rule broader than leaf
                    spec_t = spec_t[-ndim:]
                if len(spec_t) < ndim:          # stacked repeats axis etc.
                    spec_t = (None,) * (ndim - len(spec_t)) + spec_t
                # drop axes that do not divide the dim evenly
                out = []
                for dim, ax in zip(leaf.shape, spec_t):
                    size = _axes_size(mesh, ax)
                    out.append(ax if (ax is not None and dim % size == 0
                                      and dim >= size) else None)
                return P(*out)
        return P()

    return jax.tree_util.tree_map_with_path(assign, params_tree)


def _axes_size(mesh: Mesh, ax) -> int:
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        return int(np.prod([mesh.shape[a] for a in ax]))
    return mesh.shape[ax]


def _maybe(mesh: Mesh, dim: int, ax):
    """ax if it divides dim, else None."""
    return ax if (ax is not None and dim % _axes_size(mesh, ax) == 0) else None


def batch_spec(cfg: ModelConfig, batch_tree, mesh: Mesh):
    """Input shardings: batch axis over (pod, data); everything else follows."""
    F = fsdp_axes(mesh)

    def assign(path, leaf):
        b = leaf.shape[0] if leaf.shape else 0
        ax = _maybe(mesh, b, F)
        return P(ax, *([None] * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map_with_path(assign, batch_tree)


def cache_specs(cfg: ModelConfig, cache_tree, mesh: Mesh,
                paged: bool = False):
    """KV/state cache shardings.

    batch >= |pod·data|: shard batch over FSDP axes, heads over model.
    batch == 1 (long-context): shard the sequence/capacity axis over `data`
    and heads over `model` (DESIGN.md §6).

    ``paged=True`` covers the paged KV pool (serving/pool.py), whose cache
    is literally ``model.init_cache(num_blocks, block_size)``: the batch
    axis is the PHYSICAL BLOCK axis (sharded over the FSDP axes, so pool
    memory scales with the data-parallel degree) and the capacity axis is
    the within-block slot axis — never sequence-sharded, a block is the
    atomic placement unit."""
    F = fsdp_axes(mesh)
    M = "model"

    def assign(path, leaf):
        s = _path_str(path)
        shp = leaf.shape
        nd = len(shp)
        if nd == 0:
            return P()
        batch_ax = _maybe(mesh, shp[1] if nd > 1 else 0, F)  # after repeats

        def seq_ax(dim):
            if paged or batch_ax is not None:
                return None
            return _maybe(mesh, dim, "data")

        # stacked leading repeats axis -> caches look like (R, b, ...)
        if re.search(r"/(k|v)$", s) and nd == 5:       # (R, b, cap, n_kv, hd)
            return P(None, batch_ax, seq_ax(shp[2]), _maybe(mesh, shp[3], M),
                     None)
        if re.search(r"/pos$", s) and nd == 3:          # (R, b, cap)
            return P(None, batch_ax, seq_ax(shp[2]))
        if re.search(r"/(ckv|krope)$", s) and nd == 4:  # (R, b, cap, r)
            return P(None, batch_ax, seq_ax(shp[2]), None)
        if re.search(r"/ssd$", s) and nd == 5:          # (R, b, H, P, N)
            return P(None, batch_ax, _maybe(mesh, shp[2], M), None, None)
        if re.search(r"/conv$", s) and nd == 4:         # (R, b, K-1, ch)
            return P(None, batch_ax, None, _maybe(mesh, shp[3], M))
        if re.search(r"/wkv$", s) and nd == 5:          # (R, b, H, D, D)
            return P(None, batch_ax, _maybe(mesh, shp[2], M), None, None)
        if re.search(r"/(shift_tm|shift_cm)$", s) and nd == 3:
            return P(None, batch_ax, None)
        if re.search(r"/cross/", s) or re.search(r"cross", s):
            if nd == 5:                                 # (R, b, n_ctx, kv, hd)
                return P(None, batch_ax, None, _maybe(mesh, shp[3], M), None)
        if nd >= 2:
            return P(None, batch_ax, *([None] * (nd - 2)))
        return P()

    return jax.tree_util.tree_map_with_path(assign, cache_tree)


def to_shardings(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def constrain_tree(mesh: Mesh, tree, spec_tree):
    """``with_sharding_constraint`` every leaf of ``tree`` to its spec —
    the trace-time twin of ``to_shardings`` for values INSIDE a jitted body
    (the serving engine constrains its gathered paged-cache views so
    gather/scatter stay layout-preserving instead of resolving to
    replicated)."""
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, s)), tree, spec_tree,
        is_leaf=lambda x: isinstance(x, P))
