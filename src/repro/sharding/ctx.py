"""Activation-sharding policy.

GSPMD propagates shardings from the jit boundary, but inside nested scans
(layer stack × blocked-attention k-loop) propagation can resolve to
"replicated" for large intermediates — observed on the production mesh as
batch-replicated attention (16x the FLOPs).  The production-grade fix is the
standard one: explicit ``with_sharding_constraint`` on the canonical
activation layouts at block boundaries.

The policy is process-global and set by the launcher (dryrun/train/serve)
before tracing; when unset (CPU smoke tests) every helper is a no-op.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_BATCH_AXES: Optional[Tuple[str, ...]] = None
_MODEL_AXIS: Optional[str] = None
_MESH = None


def set_policy(mesh, batch_axes, model_axis="model"):
    global _BATCH_AXES, _MODEL_AXIS, _MESH
    _MESH = mesh
    _BATCH_AXES = tuple(batch_axes) if batch_axes else None
    _MODEL_AXIS = model_axis if (mesh is not None
                                 and model_axis in mesh.axis_names) else None


def clear_policy():
    set_policy(None, None)


def get_policy():
    """Snapshot of (mesh, batch_axes, model_axis) — pass back to
    ``restore_policy`` so nested scopes (the serving engine traces under
    its own mesh) don't clobber an outer launcher's policy."""
    return _MESH, _BATCH_AXES, _MODEL_AXIS


def restore_policy(snap) -> None:
    global _BATCH_AXES, _MODEL_AXIS, _MESH
    _MESH, _BATCH_AXES, _MODEL_AXIS = snap


def tp_shard_info():
    """(mesh, model_axis, batch_axes) when a policy with a >1-way model
    axis is active, else None.

    This is the routing switch for the T-local sharded QUOKA scoring path
    (core/quoka.py): with tensor parallelism active, scoring work can be
    split over the ``model`` axis along the KEY axis of the cache instead
    of under-sharding on the (possibly indivisible) KV-head axis."""
    if _MESH is None or _MODEL_AXIS is None:
        return None
    if _MESH.shape[_MODEL_AXIS] <= 1:
        return None
    return _MESH, _MODEL_AXIS, _BATCH_AXES


def _axis_size(ax) -> int:
    if _MESH is None or ax is None:
        return 1
    if isinstance(ax, tuple):
        n = 1
        for a in ax:
            n *= _MESH.shape[a]
        return n
    return _MESH.shape[ax]


def _ok(dim: int, ax) -> bool:
    s = _axis_size(ax)
    return s > 1 and dim % s == 0


def shard_batch(x, *, heads_axis: Optional[int] = None):
    """Constrain dim0 to the batch axes and (optionally) a heads dim to the
    model axis.  No-op when no policy is set or dims don't divide."""
    if _MESH is None or _BATCH_AXES is None or x.ndim == 0:
        return x
    spec = [None] * x.ndim
    if _ok(x.shape[0], _BATCH_AXES):
        spec[0] = _BATCH_AXES
    if heads_axis is not None and _MODEL_AXIS is not None \
            and _ok(x.shape[heads_axis], _MODEL_AXIS):
        spec[heads_axis] = _MODEL_AXIS
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


def shard_activation(x):
    """(b, t, d) residual-stream constraint."""
    return shard_batch(x)


def shard_heads(x, heads_axis: int):
    return shard_batch(x, heads_axis=heads_axis)


def shard_spec(x, axes):
    """Constrain with an explicit per-dim axis tuple, e.g. the MoE dispatch
    buffer (E, C, d) -> ("model", "data", None).  "data" means the FSDP/batch
    axes; dims that don't divide are left unsharded; no-op without policy."""
    if _MESH is None or _BATCH_AXES is None:
        return x
    spec = []
    for dim, ax in zip(x.shape, axes):
        if ax == "data":
            ax = _BATCH_AXES
        elif ax == "model":
            ax = _MODEL_AXIS
        spec.append(ax if (ax is not None and _ok(dim, ax)) else None)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))
