"""Mixture-of-Experts FFN (OLMoE / DeepSeek-V3 style).

Two dispatch paths:

  * ``dense``    — weighted sum over ALL experts via einsum.  Exact, simple,
                   used at smoke-test scale (<= 4 experts); FLOP-dishonest at
                   production scale, so never used there.
  * ``capacity`` — GShard-style fixed-capacity scatter/gather.  Tokens are
                   ranked within their expert via a one-hot cumsum, dropped
                   beyond capacity C = ceil(k*N/E*cap_factor), scattered into
                   an (E, C, d) buffer, batch-matmul'd per expert, gathered
                   back weighted.  The buffer shards E over `model` (expert
                   parallelism); pjit turns the scatter/gather into
                   all-to-all-like collectives.  FLOPs ≈ 1.25x active — honest
                   for the roofline.

Router: softmax gate, top-k, renormalised; aux load-balance loss
``E * sum_e f_e * p_e`` (Switch/GShard) accumulated into ctx["aux_loss"].
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models.layers import _act, linear, linear_init, mlp, mlp_init


def moe_init(key, cfg: ModelConfig):
    e = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    std = 1.0 / math.sqrt(d)
    p = {
        "router": linear_init(ks[0], d, e.n_experts),
        # stacked expert weights: (E, d, f) / (E, f, d)
        "gate": jax.random.normal(ks[1], (e.n_experts, d, e.d_expert)) * std,
        "up": jax.random.normal(ks[2], (e.n_experts, d, e.d_expert)) * std,
        "down": jax.random.normal(ks[3], (e.n_experts, e.d_expert, d))
                * (1.0 / math.sqrt(e.d_expert)),
    }
    if e.n_shared:
        p["shared"] = mlp_init(jax.random.fold_in(key, 9), d,
                               e.n_shared * e.d_expert)
    return p


def _router(p, x, e: MoEConfig):
    """x: (N, d) -> (weights (N, k), ids (N, k), aux_loss scalar)."""
    logits = linear(p["router"], x).astype(jnp.float32)       # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, e.top_k)                    # (N, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss
    f = jnp.mean(jax.nn.one_hot(ids, e.n_experts, dtype=jnp.float32),
                 axis=(0, 1)) * e.top_k                       # fraction routed
    pbar = jnp.mean(probs, axis=0)
    aux = e.n_experts * jnp.sum(f * pbar)
    return w.astype(x.dtype), ids, aux


def _expert_ffn(p, h, act: str):
    """h: (E, C, d) -> (E, C, d) via per-expert SwiGLU."""
    g = jnp.einsum("ecd,edf->ecf", h, p["gate"].astype(h.dtype))
    u = jnp.einsum("ecd,edf->ecf", h, p["up"].astype(h.dtype))
    return jnp.einsum("ecf,efd->ecd", _act(act, g) * u,
                      p["down"].astype(h.dtype))


def moe_apply(p, x, cfg: ModelConfig, ctx: Optional[dict] = None):
    """x: (b, t, d) -> (b, t, d).  Adds aux loss into ctx['aux_loss']."""
    e = cfg.moe
    b, t, d = x.shape
    xf = x.reshape(-1, d)                                     # (N, d)
    n = xf.shape[0]
    w, ids, aux = _router(p, xf, e)
    if ctx is not None:
        ctx["aux_loss"] = ctx.get("aux_loss", 0.0) + e.router_aux_coef * aux

    if e.dispatch == "dense":
        gates = jnp.zeros((n, e.n_experts), x.dtype).at[
            jnp.arange(n)[:, None], ids].set(w)               # (N, E)
        h = jnp.einsum("nd,edf->nef", xf, p["gate"].astype(x.dtype))
        u = jnp.einsum("nd,edf->nef", xf, p["up"].astype(x.dtype))
        y = jnp.einsum("nef,efd->ned", _act(cfg.act, h) * u,
                       p["down"].astype(x.dtype))
        out = jnp.einsum("ned,ne->nd", y, gates)
    elif e.dispatch == "capacity":
        cap = int(math.ceil(e.top_k * n / e.n_experts * e.capacity_factor))
        cap = max(cap, 1)
        flat_e = ids.reshape(-1)                              # (N*k,)
        # rank-within-expert via one-hot cumsum.  (§Perf B1 measured the
        # "obvious" sort-based ranking at 28x MORE collective traffic — a
        # global argsort over the data-sharded token axis is a distributed
        # sort; the cumsum is a local partial-sum + small cross-shard offset.)
        onehot = jax.nn.one_hot(flat_e, e.n_experts, dtype=jnp.int32)
        ranks = jnp.cumsum(onehot, axis=0) - onehot           # (N*k, E)
        pos = jnp.take_along_axis(ranks, flat_e[:, None], axis=1)[:, 0]
        keep = pos < cap
        # scatter tokens (duplicated per choice) into the expert buffer
        xe = jnp.repeat(xf, e.top_k, axis=0)                  # (N*k, d)
        safe_pos = jnp.where(keep, pos, cap - 1)
        # NOTE (§Perf B2): forcing the buffer to P(model, data, None) here
        # measured 28x MORE collective traffic than letting GSPMD place it —
        # the token->buffer scatter then crossed two mesh axes at once.
        # Propagation picks a single-axis reshard; leave it alone.
        buf = jnp.zeros((e.n_experts, cap, d), x.dtype)
        buf = buf.at[flat_e, safe_pos].add(
            jnp.where(keep[:, None], xe, 0))
        yb = _expert_ffn(p, buf, cfg.act)                     # (E, C, d)
        back = yb[flat_e, safe_pos]                           # (N*k, d)
        back = jnp.where(keep[:, None], back, 0)
        out = jnp.sum(
            back.reshape(n, e.top_k, d) * w[..., None], axis=1)
    else:
        raise ValueError(e.dispatch)

    if e.n_shared:
        out = out + mlp(p["shared"], xf, cfg.act)
    return out.reshape(b, t, d)
