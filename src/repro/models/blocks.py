"""Transformer-block zoo.

Every block implements the same protocol so the scanned stack
(models/stack.py) can treat a layer uniformly:

    init(key) -> params
    train(p, x, pos, ctx)              -> (x, aux)          # full-sequence
    cache_spec(batch, cap, dtype)      -> BlockCache
    apply(p, x, pos, cache, ctx, plan=None)
        -> (x, cache, aux, plan)                            # prefill chunk
                                                            # or decode (t=1)

``plan`` is the cross-layer ``core/plan.py::PlanCarry`` (None disables
reuse: every selecting block builds its own plan).  Selecting blocks
additionally implement ``plan_carry_shape(cache, t, method, qcfg)`` so the
stack can decide statically whether a shared carry is geometrically valid.

``ctx`` (dict):
    method     selection method name ("full" = dense attention)
    qcfg       QuokaConfig
    enc_out    whisper encoder output (b, n_ctx, d) — train/cache-build only
    shared     params of the zamba2 shared attention block
    slot       cache write slot of the chunk (traced scalar, or per-row (b,)
               under continuous batching).  Distinct from ``pos``: pad slots
               carry pos == -1 while still occupying a cache slot.  Absent ->
               derived as pos[0, 0] (the legacy unpadded path).
    layer_idx  traced GLOBAL layer index (set by the stack scan when plan
               reuse is on) — drives the reuse_interval/correction schedule.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import plan as plan_mod
from repro.core import selection as sel_mod
from repro.core.attention import (NEG_INF, attention_with_positions,
                                  dense_attention, position_mask)
from repro.kernels import ops as kops
from repro.models import mamba2, moe, rwkv6
from repro.models.layers import (layernorm, layernorm_init, linear,
                                 linear_init, mlp, mlp_init, rmsnorm,
                                 rmsnorm_init, rope)
from repro.serving.cache import (BlockCache, CrossKV, KVCache, LatentCache,
                                 kv_init, kv_write, kv_write_ring,
                                 latent_init, latent_write)
from repro.sharding import ctx as shctx


def _chunk_slot(ctx, pos):
    """Cache write slot for the current chunk: explicit ``ctx["slot"]`` when
    provided (padded prompts / continuous batching), else the first query
    position (slot == position on the legacy unpadded path)."""
    slot = ctx.get("slot") if isinstance(ctx, dict) else None
    return pos[0, 0] if slot is None else slot


def _norm_fns(cfg: ModelConfig):
    if cfg.family == "audio":          # whisper uses LayerNorm
        return layernorm_init, lambda p, x: layernorm(p, x)
    return rmsnorm_init, lambda p, x: rmsnorm(p, x, cfg.norm_eps)


# ============================================================================
# GQA attention block (dense / sliding-window / MoE-FFN / encoder)
# ============================================================================

class AttnBlock:
    def __init__(self, cfg: ModelConfig, kind: str):
        self.cfg = cfg
        self.kind = kind
        self.window = cfg.sliding_window if kind == "attn_local" else None
        self.causal = kind != "enc_attn"
        self.is_moe = kind == "attn_moe"
        self.norm_init, self.norm = _norm_fns(cfg)

    # ---- params ----
    def init(self, key):
        cfg = self.cfg
        d, hd = cfg.d_model, cfg.resolved_head_dim
        ks = jax.random.split(key, 6)
        p = {
            "ln1": self.norm_init(d),
            "wq": linear_init(ks[0], d, cfg.n_heads * hd),
            "wk": linear_init(ks[1], d, cfg.n_kv_heads * hd),
            "wv": linear_init(ks[2], d, cfg.n_kv_heads * hd),
            "wo": linear_init(ks[3], cfg.n_heads * hd, d,
                              std=1.0 / math.sqrt(cfg.n_heads * hd * 2 * cfg.n_layers)),
            "ln2": self.norm_init(d),
        }
        if self.is_moe:
            p["moe"] = moe.moe_init(ks[4], cfg)
        else:
            p["mlp"] = mlp_init(ks[4], d, cfg.d_ff,
                                gated=cfg.act != "gelu")
        return p

    # ---- helpers ----
    def _qkv(self, p, x, pos):
        cfg = self.cfg
        b, t, _ = x.shape
        hd = cfg.resolved_head_dim
        q = linear(p["wq"], x).reshape(b, t, cfg.n_heads, hd)
        k = linear(p["wk"], x).reshape(b, t, cfg.n_kv_heads, hd)
        v = linear(p["wv"], x).reshape(b, t, cfg.n_kv_heads, hd)
        if cfg.use_rope:
            q = rope(q, pos, cfg.rope_theta)
            k = rope(k, pos, cfg.rope_theta)
        return (shctx.shard_heads(q, 2), shctx.shard_heads(k, 2),
                shctx.shard_heads(v, 2))

    def _ffn(self, p, x, ctx):
        cfg = self.cfg
        h = self.norm(p["ln2"], x)
        if self.is_moe:
            y = moe.moe_apply(p["moe"], h, cfg, ctx)
            aux = ctx.pop("aux_loss", 0.0) if isinstance(ctx, dict) else 0.0
            return x + y, aux
        return x + mlp(p["mlp"], h, cfg.act), 0.0

    # ---- modes ----
    def train(self, p, x, pos, ctx):
        q, k, v = self._qkv(p, self.norm(p["ln1"], x), pos)
        att = attention_with_positions(q, k, v, pos, pos,
                                       causal=self.causal, window=self.window)
        b, t = x.shape[:2]
        x = x + linear(p["wo"], att.reshape(b, t, -1))
        return self._ffn(p, x, dict(ctx) if ctx else {})

    def cache_spec(self, batch, cap, dtype):
        cfg = self.cfg
        if self.kind == "enc_attn":
            return BlockCache()
        if self.window is not None:
            cap = min(cap, self.window)
        return BlockCache(kv=kv_init(batch, cap, cfg.n_kv_heads,
                                     cfg.resolved_head_dim, dtype))

    def plan_carry_shape(self, cache, t: int, method: str, qcfg):
        """Static ``SelectionPlan.idx`` shape this block would build for a
        t-token chunk (from possibly layer-stacked cache leaves), or None
        when the block never selects (encoder / dense fallback / grid
        mismatch) — which disables the shared cross-layer carry."""
        kv = getattr(cache, "kv", None)
        if self.kind == "enc_attn" or kv is None or kv == ():
            return None
        b, cap, n_kv = kv.k.shape[-4], kv.k.shape[-3], kv.k.shape[-2]
        budget = sel_mod.resolve_budget(qcfg, cap)
        if method == "full" or cap <= budget + t:
            return None
        if plan_mod.grid(qcfg) > 1 and cap % plan_mod.grid(qcfg):
            return None
        return plan_mod.plan_idx_shape(qcfg, b, n_kv, cap, budget)

    def apply(self, p, x, pos, cache: BlockCache, ctx, plan=None):
        """Prefill chunk or decode step (t == chunk size or 1)."""
        cfg = self.cfg
        if self.kind == "enc_attn":
            y, aux = self.train(p, x, pos, ctx)
            return y, cache, aux, plan
        b, t, _ = x.shape
        q, k, v = self._qkv(p, self.norm(p["ln1"], x), pos)
        start = _chunk_slot(ctx, pos)
        kv = cache.kv
        write = kv_write_ring if self.window is not None else kv_write
        kv = write(kv, k, v, start, pos_new=pos)

        method = ctx.get("method", "full")
        budget = sel_mod.resolve_budget(ctx["qcfg"], kv.capacity) \
            if method != "full" else 0
        if method == "full" or kv.capacity <= budget + t:
            att = attention_with_positions(q, kv.k, kv.v, pos, kv.pos,
                                           causal=True, window=self.window)
            if isinstance(ctx, dict) and ctx.get("obs"):
                ctx["_obs"] = plan_mod.dense_obs(kv.pos, start)
        elif plan_mod.fused_route(ctx["qcfg"], method, kv.k,
                                  window=self.window):
            # gather-free path: the plan's block ids drive the kernel's
            # index maps directly — the chunk KV was just written into the
            # cache above, so the kernel reads it from there rather than
            # from a [budget | chunk] concat
            att, plan = plan_mod.fused_attend_with_ctx(
                ctx, plan, method, q, kv.k, kv.v, kv.pos, start,
                ctx["qcfg"], budget=budget, q_valid=pos >= 0)
        else:
            sel, plan = plan_mod.select_with_ctx(
                ctx, plan, method, q, kv.k, kv.v, kv.pos, start,
                ctx["qcfg"], budget=budget, q_valid=pos >= 0)
            att = self._selected_attention(q, k, v, pos, sel,
                                           backend=ctx.get("backend"))
        x = x + linear(p["wo"], att.reshape(b, t, -1))
        x, aux = self._ffn(p, x, dict(ctx) if ctx else {})
        return x, cache._replace(kv=kv), aux, plan

    def _selected_attention(self, q, k_chunk, v_chunk, pos, sel,
                            backend=None):
        """Attention over [selected budget | current chunk] via the kernel
        facade: the budget is an unconditioned prefix (`boundary`), budget
        padding is masked through per-KV-head `k_valid` (sel.pos == -1).

        Sliding-window layers keep the masked dense path — the window
        constraint on selected keys is per-QUERY and cannot be expressed by
        the kernel's static boundary + per-key validity contract.
        """
        b, t = q.shape[:2]
        n_kv = k_chunk.shape[2]
        k_cat = jnp.concatenate([sel.k, k_chunk], axis=1)
        v_cat = jnp.concatenate([sel.v, v_chunk], axis=1)
        # chunk keys with pos == -1 are pad slots — never attendable
        chunk_valid = jnp.broadcast_to((pos >= 0)[:, None, :], (b, n_kv, t))
        if self.window is None:
            k_valid = jnp.concatenate([sel.pos >= 0, chunk_valid], axis=-1)
            return kops.attention(q, k_cat, v_cat, k_valid, causal=True,
                                  boundary=sel.pos.shape[-1],
                                  backend=backend, cfg=self.cfg.quoka)
        qp = pos[:, None, :, None]                       # (b,1,t,1)
        sp = sel.pos[:, :, None, :]                      # (b,n_kv,1,B)
        m_sel = (sp >= 0) & (sp > qp - self.window)
        m_sel = jnp.broadcast_to(m_sel, (b, n_kv, t, sel.pos.shape[-1]))
        tri = jnp.tril(jnp.ones((t, t), bool))
        m_chunk = tri[None, None] & chunk_valid[:, :, None, :]
        m_chunk = jnp.broadcast_to(m_chunk, (b, n_kv, t, t))
        mask = jnp.concatenate([m_sel, m_chunk], axis=-1)
        return dense_attention(q, k_cat, v_cat, mask)


# ============================================================================
# DeepSeek MLA block (absorbed-latent attention; compressed KV cache)
# ============================================================================

class MLABlock:
    def __init__(self, cfg: ModelConfig, kind: str):
        self.cfg = cfg
        self.kind = kind
        self.is_moe = kind == "mla_moe"
        self.norm_init, self.norm = _norm_fns(cfg)
        m = cfg.mla
        self.scale = 1.0 / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)

    def init(self, key):
        cfg = self.cfg
        m = cfg.mla
        d, h = cfg.d_model, cfg.n_heads
        ks = jax.random.split(key, 8)
        p = {
            "ln1": self.norm_init(d),
            "wq_a": linear_init(ks[0], d, m.q_lora_rank),
            "q_ln": rmsnorm_init(m.q_lora_rank),
            "wq_b": linear_init(ks[1], m.q_lora_rank,
                                h * (m.qk_nope_dim + m.qk_rope_dim)),
            "wkv_a": linear_init(ks[2], d, m.kv_lora_rank + m.qk_rope_dim),
            "kv_ln": rmsnorm_init(m.kv_lora_rank),
            # decompression weights, stored head-major for absorption
            "wk_b": jax.random.normal(ks[3], (m.kv_lora_rank, h, m.qk_nope_dim))
                    / math.sqrt(m.kv_lora_rank),
            "wv_b": jax.random.normal(ks[4], (m.kv_lora_rank, h, m.v_head_dim))
                    / math.sqrt(m.kv_lora_rank),
            "wo": linear_init(ks[5], h * m.v_head_dim, d,
                              std=1.0 / math.sqrt(h * m.v_head_dim * 2 * cfg.n_layers)),
            "ln2": self.norm_init(d),
        }
        if self.is_moe:
            p["moe"] = moe.moe_init(ks[6], cfg)
        else:
            p["mlp"] = mlp_init(ks[6], d, cfg.d_ff)
        return p

    # ---- projections ----
    def _queries(self, p, h, pos):
        cfg, m = self.cfg, self.cfg.mla
        b, t, _ = h.shape
        cq = rmsnorm(p["q_ln"], linear(p["wq_a"], h), cfg.norm_eps)
        q = linear(p["wq_b"], cq).reshape(b, t, cfg.n_heads,
                                          m.qk_nope_dim + m.qk_rope_dim)
        q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
        q_rope = rope(q_rope, pos, cfg.rope_theta)
        # absorbed: q_abs[h] = q_nope[h] @ W_uk[h]  -> latent space
        q_abs = jnp.einsum("bthn,rhn->bthr", q_nope,
                           p["wk_b"].astype(q_nope.dtype))
        return q_abs, q_rope

    def _latent_kv(self, p, h, pos):
        cfg, m = self.cfg, self.cfg.mla
        kv = linear(p["wkv_a"], h)
        ckv = rmsnorm(p["kv_ln"], kv[..., :m.kv_lora_rank], cfg.norm_eps)
        kr = kv[..., m.kv_lora_rank:]
        kr = rope(kr[:, :, None, :], pos, cfg.rope_theta)[:, :, 0, :]
        return ckv, kr

    def _absorbed_full(self, p, q_abs, q_rope, ckv, krope, q_pos, k_pos):
        """Full (position-masked) absorbed attention; streams key blocks via
        blocked_attention above the materialisation threshold so the T² score
        matrix never hits HBM (train / dense-prefill / long decode)."""
        from repro.core.attention import BLOCKED_THRESHOLD, blocked_attention
        m = self.cfg.mla
        b, t = q_abs.shape[:2]
        tk = ckv.shape[1]
        if tk > BLOCKED_THRESHOLD:
            qc = jnp.concatenate([q_abs, q_rope], axis=-1)
            kc = jnp.concatenate([ckv, krope], axis=-1)[:, :, None, :]
            o_lat = blocked_attention(qc, kc, ckv[:, :, None, :],
                                      q_pos, k_pos, causal=True,
                                      scale=self.scale)
            out = jnp.einsum("bthr,rhv->bthv", o_lat.astype(jnp.float32),
                             p["wv_b"].astype(jnp.float32))
            return out.reshape(b, t, -1).astype(q_abs.dtype)
        mask = position_mask(q_pos, k_pos, causal=True)
        return self._absorbed_attention(p, q_abs, q_rope, ckv, krope, mask)

    def _absorbed_attention(self, p, q_abs, q_rope, ckv, krope, mask):
        """Attention entirely in latent space (the MLA deployment trick)."""
        m = self.cfg.mla
        logits = (jnp.einsum("bthr,bsr->bhts", q_abs.astype(jnp.float32),
                             ckv.astype(jnp.float32))
                  + jnp.einsum("bthr,bsr->bhts", q_rope.astype(jnp.float32),
                               krope.astype(jnp.float32))) * self.scale
        logits = jnp.where(mask, logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        o_lat = jnp.einsum("bhts,bsr->bthr", probs, ckv.astype(jnp.float32))
        out = jnp.einsum("bthr,rhv->bthv", o_lat,
                         p["wv_b"].astype(jnp.float32))
        b, t = q_abs.shape[:2]
        return out.reshape(b, t, -1).astype(q_abs.dtype)

    def _ffn(self, p, x, ctx):
        cfg = self.cfg
        h = self.norm(p["ln2"], x)
        if self.is_moe:
            c = dict(ctx) if ctx else {}
            y = moe.moe_apply(p["moe"], h, cfg, c)
            return x + y, c.pop("aux_loss", 0.0)
        return x + mlp(p["mlp"], h, cfg.act), 0.0

    # ---- modes ----
    def train(self, p, x, pos, ctx):
        h = self.norm(p["ln1"], x)
        q_abs, q_rope = self._queries(p, h, pos)
        ckv, kr = self._latent_kv(p, h, pos)
        att = self._absorbed_full(p, q_abs, q_rope, ckv, kr, pos, pos)
        x = x + linear(p["wo"], att)
        return self._ffn(p, x, ctx)

    def cache_spec(self, batch, cap, dtype):
        m = self.cfg.mla
        return BlockCache(latent=latent_init(batch, cap, m.kv_lora_rank,
                                             m.qk_rope_dim, dtype))

    def plan_carry_shape(self, cache, t: int, method: str, qcfg):
        """Latent selection geometry: one shared 'KV head' (n_kv == 1)."""
        lat = getattr(cache, "latent", None)
        if lat is None or lat == ():
            return None
        b, cap = lat.ckv.shape[-3], lat.ckv.shape[-2]
        budget = sel_mod.resolve_budget(qcfg, cap)
        if method == "full" or cap <= budget + t:
            return None
        if plan_mod.grid(qcfg) > 1 and cap % plan_mod.grid(qcfg):
            return None
        return plan_mod.plan_idx_shape(qcfg, b, 1, cap, budget)

    def apply(self, p, x, pos, cache: BlockCache, ctx, plan=None):
        cfg, m = self.cfg, self.cfg.mla
        b, t, _ = x.shape
        h = self.norm(p["ln1"], x)
        q_abs, q_rope = self._queries(p, h, pos)
        ckv, kr = self._latent_kv(p, h, pos)
        start = _chunk_slot(ctx, pos)
        lat = latent_write(cache.latent, ckv, kr, start, pos_new=pos)

        method = ctx.get("method", "full")
        budget = sel_mod.resolve_budget(ctx["qcfg"], lat.capacity) \
            if method != "full" else 0
        if method == "full" or lat.capacity <= budget + t:
            att = self._absorbed_full(p, q_abs, q_rope, lat.ckv,
                                      lat.krope, pos, lat.pos)
            if isinstance(ctx, dict) and ctx.get("obs"):
                ctx["_obs"] = plan_mod.dense_obs(lat.pos, start)
        else:
            att, plan = self._selected_attention(p, q_abs, q_rope, ckv, kr,
                                                 pos, lat, start, ctx, plan)
        x = x + linear(p["wo"], att)
        x, aux = self._ffn(p, x, ctx)
        return x, cache._replace(latent=lat), aux, plan

    def _selected_attention(self, p, q_abs, q_rope, ckv_chunk, kr_chunk,
                            pos, lat: LatentCache, start, ctx, plan=None):
        """QUOKA (or baseline) on the COMPRESSED latent: one shared 'KV head'
        per token — scoring queries are the absorbed per-head queries, so
        pre-aggregation averages over all n_heads (n_kv == 1).

        The post-selection attention runs through the kernel facade in
        latent space: queries/keys are the concatenated [absorbed | rope]
        vectors, values are the latent ckv zero-padded to the key width
        (a zero value-tail does not change the softmax; the padded output
        columns are sliced off before the W_uv decompression)."""
        b, t = q_abs.shape[:2]
        qc = ctx["qcfg"]
        method = ctx.get("method", "quoka")
        latent_keys = jnp.concatenate([lat.ckv, lat.krope],
                                      axis=-1)[:, :, None, :]   # (b,T,1,r+rd)
        q_score = jnp.concatenate([q_abs, q_rope], axis=-1)      # (b,t,h,·)
        sel, plan = plan_mod.select_with_ctx(
            ctx, plan, method, q_score, latent_keys, latent_keys, lat.pos,
            start, qc, q_valid=pos >= 0)
        r = self.cfg.mla.kv_lora_rank
        ckv_sel, kr_sel = sel.k[..., 0, :r], sel.k[..., 0, r:]   # (b,B,·)
        ckv_cat = jnp.concatenate([ckv_sel, ckv_chunk], axis=1)
        kr_cat = jnp.concatenate([kr_sel, kr_chunk], axis=1)
        k_cat = jnp.concatenate([ckv_cat, kr_cat], axis=-1)[:, :, None, :]
        rd = k_cat.shape[-1] - r
        v_pad = jnp.pad(ckv_cat, ((0, 0), (0, 0), (0, rd)))[:, :, None, :]
        k_valid = jnp.concatenate(
            [sel.pos >= 0, (pos >= 0)[:, None, :]], axis=-1)
        o_lat = kops.attention(q_score, k_cat, v_pad, k_valid, causal=True,
                               boundary=sel.pos.shape[-1], scale=self.scale,
                               backend=ctx.get("backend"), cfg=qc)[..., :r]
        out = jnp.einsum("bthr,rhv->bthv", o_lat.astype(jnp.float32),
                         p["wv_b"].astype(jnp.float32))
        return out.reshape(b, t, -1).astype(q_abs.dtype), plan


# ============================================================================
# Mamba2 block (optionally followed by the zamba2 shared attention block)
# ============================================================================

class MambaBlock:
    def __init__(self, cfg: ModelConfig, kind: str):
        self.cfg = cfg
        self.kind = kind
        self.with_shared = kind == "mamba_shared_attn"
        self.norm_init, self.norm = _norm_fns(cfg)
        if self.with_shared:
            self.shared = AttnBlock(cfg, "attn")

    def init(self, key):
        return {"ln": self.norm_init(self.cfg.d_model),
                "mamba": mamba2.mamba_init(key, self.cfg)}

    def cache_spec(self, batch, cap, dtype):
        mc = mamba2.mamba_cache_init(batch, self.cfg, dtype)
        if self.with_shared:
            kvc = self.shared.cache_spec(batch, cap, dtype)
            return BlockCache(mamba=mc, kv=kvc.kv)
        return BlockCache(mamba=mc)

    def train(self, p, x, pos, ctx):
        cache = mamba2.mamba_cache_init(x.shape[0], self.cfg, x.dtype)
        y, _ = mamba2.mamba_apply(p["mamba"], self.norm(p["ln"], x),
                                  cache, self.cfg)
        x = x + y
        aux = 0.0
        if self.with_shared:
            x, aux = self.shared.train(ctx["shared"], x, pos, ctx)
        return x, aux

    def plan_carry_shape(self, cache, t: int, method: str, qcfg):
        if not self.with_shared:
            return None
        return self.shared.plan_carry_shape(cache, t, method, qcfg)

    def apply(self, p, x, pos, cache: BlockCache, ctx, plan=None):
        y, mc = mamba2.mamba_apply(p["mamba"], self.norm(p["ln"], x),
                                   cache.mamba, self.cfg)
        x = x + y
        aux = 0.0
        if self.with_shared:
            x, kvc, aux, plan = self.shared.apply(ctx["shared"], x, pos,
                                                  BlockCache(kv=cache.kv),
                                                  ctx, plan=plan)
            return x, cache._replace(mamba=mc, kv=kvc.kv), aux, plan
        return x, cache._replace(mamba=mc), aux, plan


# ============================================================================
# RWKV6 block — unified segment apply (train == prefill with fresh state)
# ============================================================================

class RWKVBlock:
    def __init__(self, cfg: ModelConfig, kind: str = "rwkv"):
        self.cfg = cfg
        self.kind = "rwkv"
        self.norm_init, self.norm = _norm_fns(cfg)

    def init(self, key):
        p = rwkv6.rwkv_init(key, self.cfg)
        p["ln1"] = self.norm_init(self.cfg.d_model)
        p["ln2"] = self.norm_init(self.cfg.d_model)
        return p

    def cache_spec(self, batch, cap, dtype):
        return BlockCache(rwkv=rwkv6.rwkv_cache_init(batch, self.cfg, dtype))

    def train(self, p, x, pos, ctx):
        cache = rwkv6.rwkv_cache_init(x.shape[0], self.cfg, x.dtype)
        y, _, _ = self._run(p, x, cache)
        return y, 0.0

    def apply(self, p, x, pos, cache: BlockCache, ctx, plan=None):
        y, new, _ = self._run(p, x, cache.rwkv)
        return y, cache._replace(rwkv=new), 0.0, plan

    def _run(self, p, x, rc):
        y, sh_tm, wkv = rwkv6.time_mix(p["tm"], self.norm(p["ln1"], x),
                                       rc.shift_tm, rc.wkv, self.cfg)
        x = x + y
        y, sh_cm = rwkv6.channel_mix(p["cm"], self.norm(p["ln2"], x),
                                     rc.shift_cm)
        x = x + y
        new = rc._replace(shift_tm=sh_tm.astype(rc.shift_tm.dtype),
                          shift_cm=sh_cm.astype(rc.shift_cm.dtype), wkv=wkv)
        return x, new, 0.0


# ============================================================================
# Whisper decoder block: causal self-attn + cross-attn + MLP
# ============================================================================

class DecCrossBlock:
    def __init__(self, cfg: ModelConfig, kind: str = "dec_cross"):
        self.cfg = cfg
        self.kind = "dec_cross"
        self.norm_init, self.norm = _norm_fns(cfg)
        self.self_attn = AttnBlock(cfg, "attn")   # reuse qkv/selection logic

    def init(self, key):
        cfg = self.cfg
        d, hd = cfg.d_model, cfg.resolved_head_dim
        ks = jax.random.split(key, 8)
        return {
            "self": self.self_attn.init(ks[0]),     # ln1/wq/wk/wv/wo/ln2/mlp
            "ln_x": self.norm_init(d),
            "xq": linear_init(ks[1], d, cfg.n_heads * hd),
            "xk": linear_init(ks[2], d, cfg.n_kv_heads * hd),
            "xv": linear_init(ks[3], d, cfg.n_kv_heads * hd),
            "xo": linear_init(ks[4], cfg.n_heads * hd, d),
        }

    def cache_spec(self, batch, cap, dtype):
        cfg = self.cfg
        base = self.self_attn.cache_spec(batch, cap, dtype)
        n_ctx = cfg.encoder.n_ctx
        cross = CrossKV(
            k=jnp.zeros((batch, n_ctx, cfg.n_kv_heads,
                         cfg.resolved_head_dim), dtype),
            v=jnp.zeros((batch, n_ctx, cfg.n_kv_heads,
                         cfg.resolved_head_dim), dtype))
        return base._replace(cross=cross)

    def build_cross(self, p, enc_out) -> CrossKV:
        cfg = self.cfg
        b, s, _ = enc_out.shape
        hd = cfg.resolved_head_dim
        k = linear(p["xk"], enc_out).reshape(b, s, cfg.n_kv_heads, hd)
        v = linear(p["xv"], enc_out).reshape(b, s, cfg.n_kv_heads, hd)
        return CrossKV(k=k, v=v)

    def _cross(self, p, x, cross: CrossKV):
        cfg = self.cfg
        b, t, _ = x.shape
        hd = cfg.resolved_head_dim
        h = self.norm(p["ln_x"], x)
        q = linear(p["xq"], h).reshape(b, t, cfg.n_heads, hd)
        att = dense_attention(q, cross.k, cross.v)      # non-causal
        return x + linear(p["xo"], att.reshape(b, t, -1))

    def train(self, p, x, pos, ctx):
        # self attention sub-block (with its own MLP) then cross attention
        sp = dict(p["self"])
        mlp_p, ln2 = sp["mlp"], sp["ln2"]
        x, _, _ = self._self_only(sp, x, pos, ctx, train=True)
        cross = self.build_cross(p, ctx["enc_out"])
        x = self._cross(p, x, cross)
        x = x + mlp(mlp_p, self.norm(ln2, x), self.cfg.act)
        return x, 0.0

    def _self_only(self, sp, x, pos, ctx, train: bool, cache=None,
                   plan=None):
        """Self-attention + residual, WITHOUT the MLP of AttnBlock."""
        a = self.self_attn
        q, k, v = a._qkv(sp, self.norm(sp["ln1"], x), pos)
        b, t = x.shape[:2]
        if train:
            att = attention_with_positions(q, k, v, pos, pos, causal=True)
            return x + linear(sp["wo"], att.reshape(b, t, -1)), None, plan
        start = _chunk_slot(ctx, pos)
        kv = kv_write(cache, k, v, start, pos_new=pos)
        method = ctx.get("method", "full")
        budget = sel_mod.resolve_budget(ctx["qcfg"], kv.capacity) \
            if method != "full" else 0
        if method == "full" or kv.capacity <= budget + t:
            att = attention_with_positions(q, kv.k, kv.v, pos, kv.pos,
                                           causal=True)
            if isinstance(ctx, dict) and ctx.get("obs"):
                ctx["_obs"] = plan_mod.dense_obs(kv.pos, start)
        elif plan_mod.fused_route(ctx["qcfg"], method, kv.k):
            att, plan = plan_mod.fused_attend_with_ctx(
                ctx, plan, method, q, kv.k, kv.v, kv.pos, start,
                ctx["qcfg"], budget=budget, q_valid=pos >= 0)
        else:
            s, plan = plan_mod.select_with_ctx(
                ctx, plan, method, q, kv.k, kv.v, kv.pos, start,
                ctx["qcfg"], budget=budget, q_valid=pos >= 0)
            att = a._selected_attention(q, k, v, pos, s,
                                        backend=ctx.get("backend"))
        return x + linear(sp["wo"], att.reshape(b, t, -1)), kv, plan

    def plan_carry_shape(self, cache, t: int, method: str, qcfg):
        return self.self_attn.plan_carry_shape(cache, t, method, qcfg)

    def apply(self, p, x, pos, cache: BlockCache, ctx, plan=None):
        sp = p["self"]
        x, kv, plan = self._self_only(sp, x, pos, ctx, train=False,
                                      cache=cache.kv, plan=plan)
        x = self._cross(p, x, cache.cross)
        x = x + mlp(sp["mlp"], self.norm(sp["ln2"], x), self.cfg.act)
        return x, cache._replace(kv=kv), 0.0, plan


# ============================================================================

_KINDS = {
    "attn": AttnBlock, "attn_local": AttnBlock, "attn_moe": AttnBlock,
    "enc_attn": AttnBlock,
    "mla": MLABlock, "mla_moe": MLABlock,
    "mamba": MambaBlock, "mamba_shared_attn": MambaBlock,
    "rwkv": RWKVBlock,
    "dec_cross": DecCrossBlock,
}


def make_block(cfg: ModelConfig, kind: str):
    return _KINDS[kind](cfg, kind)
