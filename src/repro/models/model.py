"""Unified model: embeddings + scanned block stacks + read-out, with
three entry points used across the framework:

  * ``train_logits`` / ``loss``     — full-sequence training forward
  * ``prefill``                     — CHUNKED prefill (paper Algorithm 2):
                                      a lax.scan over chunks; each chunk
                                      sub-selects the KV cache per layer
  * ``decode_step``                 — one-token decode with selection

Modality frontends (VLM patches / whisper frames) are stubs per the
assignment: the batch provides pre-computed embeddings; the in-model
projector / encoder transformer consumes them.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.blocks import DecCrossBlock, MLABlock, make_block
from repro.models.layers import (embed, embed_init, linear, linear_init,
                                 mlp_init, rmsnorm, rmsnorm_init, sinusoidal,
                                 unembed)
from repro.models.stack import Stack


class ModelCache(NamedTuple):
    stacks: Tuple            # tuple over stacks of tuple-over-positions
    enc_done: jax.Array      # () bool — whisper encoder ran (unused otherwise)


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.stacks = [Stack(cfg, period, reps)
                       for period, reps in cfg.stacks()]
        self.has_shared = any(k == "mamba_shared_attn"
                              for pd, _ in cfg.stacks() for k in pd)
        self.is_audio = cfg.family == "audio"
        self.is_vlm = cfg.family == "vlm"
        if self.is_audio:
            self.enc_stack = Stack(cfg, ("enc_attn",), cfg.encoder.n_layers)

    # ------------------------------------------------------------------
    def init(self, key) -> Dict:
        cfg = self.cfg
        ks = jax.random.split(key, 8)
        p = {
            "embed": embed_init(ks[0], cfg.vocab, cfg.d_model),
            "stacks": tuple(s.init(jax.random.fold_in(ks[1], i))
                            for i, s in enumerate(self.stacks)),
            "ln_f": rmsnorm_init(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = linear_init(ks[2], cfg.d_model, cfg.vocab)
        if self.has_shared:
            shared_blk = make_block(cfg, "attn")
            p["shared"] = shared_blk.init(ks[3])
        if self.is_audio:
            p["enc"] = {"stack": self.enc_stack.init(ks[4]),
                        "ln": rmsnorm_init(cfg.d_model)}
        if self.is_vlm:
            f = cfg.frontend
            p["proj"] = {"fc1": linear_init(ks[5], f.d_in, cfg.d_model,
                                            bias=True),
                         "fc2": linear_init(ks[6], cfg.d_model, cfg.d_model,
                                            bias=True)}
        if cfg.mtp:
            mtp_blk = MLABlock(cfg, "mla") if cfg.mla else make_block(cfg, "attn")
            p["mtp"] = {"block": mtp_blk.init(ks[7]),
                        "ln": rmsnorm_init(cfg.d_model),
                        "mix": linear_init(jax.random.fold_in(ks[7], 1),
                                           2 * cfg.d_model, cfg.d_model)}
        return p

    # ------------------------------------------------------------------
    # input embedding (modality frontends are stubs — see module docstring)
    # ------------------------------------------------------------------
    def embed_inputs(self, p, batch: Dict) -> Tuple[jax.Array, jax.Array]:
        """Returns (x (b, T, d), pos (b, T)).

        ``batch["pad"]`` (b,) optionally gives per-row LEFT-pad counts: pad
        slots get ``pos = -1`` (invalid), masking them out of attention, KV
        selection scoring and the cache — pad tokens are NOT ordinary
        context.  (Recurrent blocks still see pad embeddings sequentially;
        exact pad masking holds for attention-cache architectures.)"""
        cfg = self.cfg
        dt = cfg.compute_dtype
        tok = batch["tokens"]
        x = embed(p["embed"], tok, dt)
        if self.is_vlm:
            if batch.get("pad") is not None:
                raise ValueError("left-padding unsupported for VLM inputs")
            pe = batch["patches"].astype(dt)              # (b, n_patch, d_in)
            h = jax.nn.gelu(linear(p["proj"]["fc1"], pe))
            h = linear(p["proj"]["fc2"], h)
            x = jnp.concatenate([h, x], axis=1)
        b, t = x.shape[:2]
        pos = jnp.arange(t, dtype=jnp.int32)[None].repeat(b, 0)
        pad = batch.get("pad")
        if pad is not None:
            pad = jnp.asarray(pad, jnp.int32)
            pos = jnp.where(jnp.arange(t, dtype=jnp.int32)[None] < pad[:, None],
                            -1, pos)
        if not cfg.use_rope:
            x = x + sinusoidal(pos, cfg.d_model, dt)
        from repro.sharding import ctx as shctx
        return shctx.shard_activation(x), pos

    def encode(self, p, frames) -> jax.Array:
        """Whisper encoder over stub frame embeddings (b, n_ctx, d)."""
        cfg = self.cfg
        dt = cfg.compute_dtype
        b, s, _ = frames.shape
        pos = jnp.arange(s, dtype=jnp.int32)[None].repeat(b, 0)
        x = frames.astype(dt) + sinusoidal(pos, cfg.d_model, dt)
        x, _ = self.enc_stack.train(p["enc"]["stack"], x, pos, {})
        return rmsnorm(p["enc"]["ln"], x, cfg.norm_eps)

    def _ctx(self, p, method: str, enc_out=None,
             backend: Optional[str] = None) -> Dict:
        import dataclasses

        from repro.kernels import ops as kops
        # kernel backend resolved ONCE at trace time (env/config/hardware)
        # and baked into the qcfg handed to every layer, so the scoring
        # stage (sel_mod.select -> quoka_scores) dispatches consistently
        # with the attention stage
        be = kops.resolve_backend(backend, self.cfg.quoka)
        ctx = {"method": method,
               "qcfg": dataclasses.replace(self.cfg.quoka, backend=be),
               "backend": be}
        if self.has_shared:
            ctx["shared"] = p["shared"]
        if enc_out is not None:
            ctx["enc_out"] = enc_out
        return ctx

    def _readout(self, p, x) -> jax.Array:
        x = rmsnorm(p["ln_f"], x, self.cfg.norm_eps)
        if self.cfg.tie_embeddings:
            return unembed(p["embed"], x)
        return linear(p["lm_head"], x.astype(jnp.float32))

    # ------------------------------------------------------------------
    # training forward
    # ------------------------------------------------------------------
    def train_logits(self, p, batch: Dict) -> Tuple[jax.Array, jax.Array]:
        """Full-sequence logits.  Returns (logits (b,T,V), aux_loss)."""
        enc_out = self.encode(p, batch["frames"]) if self.is_audio else None
        x, pos = self.embed_inputs(p, batch)
        ctx = self._ctx(p, "full", enc_out)
        aux = jnp.zeros((), jnp.float32)
        for s, sp in zip(self.stacks, p["stacks"]):
            x, a = s.train(sp, x, pos, ctx)
            aux = aux + a
        hidden = x
        logits = self._readout(p, x)
        if self.cfg.mtp:
            aux = aux + self._mtp_loss(p, hidden, pos, batch, ctx)
        return logits, aux

    def _mtp_loss(self, p, hidden, pos, batch, ctx) -> jax.Array:
        """DeepSeek-V3 multi-token prediction: one extra block predicts
        token t+2 from [norm(h_t); emb(tok_{t+1})] (weight 0.3)."""
        cfg = self.cfg
        tok = batch["tokens"]
        nxt = jnp.roll(tok, -1, axis=1)
        emb_n = embed(p["embed"], nxt, hidden.dtype)
        h = rmsnorm(p["mtp"]["ln"], hidden, cfg.norm_eps)
        h = linear(p["mtp"]["mix"], jnp.concatenate([h, emb_n], axis=-1))
        blk = MLABlock(cfg, "mla") if cfg.mla else make_block(cfg, "attn")
        h, _ = blk.train(p["mtp"]["block"], h, pos, ctx)
        logits = self._readout(p, h)                    # predicts t+2
        tgt = jnp.roll(tok, -2, axis=1)
        mask = jnp.arange(tok.shape[1]) < tok.shape[1] - 2
        ll = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(ll, tgt[..., None], axis=-1)[..., 0]
        return 0.3 * jnp.mean(nll * mask[None, :])

    def loss(self, p, batch: Dict) -> jax.Array:
        """Next-token cross entropy (+ MoE/MTP aux).  For VLM the frontend
        positions are excluded; for whisper the loss is over decoder tokens."""
        logits, aux = self.train_logits(p, batch)
        tok = batch["tokens"]
        if self.is_vlm:                                  # drop patch positions
            logits = logits[:, -tok.shape[1]:]
        tgt = tok[:, 1:]
        lg = logits[:, :-1]
        ll = jax.nn.log_softmax(lg, axis=-1)
        nll = -jnp.take_along_axis(ll, tgt[..., None], axis=-1)[..., 0]
        mask = batch.get("loss_mask")
        if mask is not None:
            m = mask[:, 1:].astype(jnp.float32)
            return (nll * m).sum() / jnp.maximum(m.sum(), 1.0) + aux
        return nll.mean() + aux

    # ------------------------------------------------------------------
    # serving: chunked prefill (Algorithm 2) + decode
    # ------------------------------------------------------------------
    def init_cache(self, batch: int, cap: int) -> ModelCache:
        dt = self.cfg.compute_dtype
        return ModelCache(
            stacks=tuple(s.init_cache(batch, cap, dt) for s in self.stacks),
            enc_done=jnp.zeros((), bool),
        )

    def _apply_stacks(self, p, x, pos, cache: ModelCache, ctx):
        """Returns (x, cache, aux, obs, sel): ``obs`` is the per-layer
        LayerObs aux-stats pytree with (n_layers,) leaves in GLOBAL layer
        order when ``ctx["obs"]`` is set (core/plan.py), else None; ``sel``
        is the (b, n_blocks) int32 selection-count total over all layers
        when ``ctx["selblk"]`` is set (the prefetch oracle), else None."""
        new = []
        aux = jnp.zeros((), jnp.float32)
        plan = None           # cross-layer SelectionPlan carry (core/plan.py)
        layer0 = 0            # global layer offset for the reuse schedule
        obs = [] if ctx.get("obs") else None
        sel = None
        for s, sp, sc in zip(self.stacks, p["stacks"], cache.stacks):
            x, nc, a, plan, ob, sb = s.apply(
                sp, x, pos, sc, dict(ctx, layer0=layer0), plan=plan)
            layer0 += len(s.period) * s.repeats
            new.append(nc)
            aux = aux + a
            if obs is not None:
                obs.append(ob)
            if sb is not None:
                sel = sb if sel is None else sel + sb
        if obs is not None:
            obs = obs[0] if len(obs) == 1 else \
                jax.tree.map(lambda *ls: jnp.concatenate(ls), *obs)
        return x, cache._replace(stacks=tuple(new)), aux, obs, sel

    def _build_cross(self, p, cache: ModelCache, enc_out) -> ModelCache:
        """Fill whisper cross-attention KV (vmapped over stacked layers)."""
        blk: DecCrossBlock = self.stacks[0].blocks[0]
        new_stacks = []
        for s, sp, sc in zip(self.stacks, p["stacks"], cache.stacks):
            pos_caches = []
            for j, b in enumerate(s.blocks):
                c = sc[j]
                if b.kind == "dec_cross":
                    cross = jax.vmap(b.build_cross, in_axes=(0, None))(
                        sp[j], enc_out)
                    c = c._replace(cross=jax.tree.map(
                        lambda l: l.astype(self.cfg.compute_dtype), cross))
                pos_caches.append(c)
            new_stacks.append(tuple(pos_caches))
        return cache._replace(stacks=tuple(new_stacks),
                              enc_done=jnp.ones((), bool))

    def prefill(self, p, batch: Dict, cache: ModelCache,
                method: Optional[str] = None,
                backend: Optional[str] = None
                ) -> Tuple[jax.Array, ModelCache]:
        """Chunked prefill of the full prompt.  Returns (last-position
        logits (b, V), filled cache)."""
        cfg = self.cfg
        method = method or cfg.quoka.method
        if self.is_audio:
            enc_out = self.encode(p, batch["frames"])
            cache = self._build_cross(p, cache, enc_out)
        x_all, pos_all = self.embed_inputs(p, batch)
        b, t, d = x_all.shape
        bcp = min(cfg.quoka.chunk_size, t)
        assert t % bcp == 0, f"prompt length {t} must be a multiple of {bcp}"
        nc = t // bcp
        xs = x_all.reshape(b, nc, bcp, d).swapaxes(0, 1)
        ps = pos_all.reshape(b, nc, bcp).swapaxes(0, 1)
        # write SLOT of each chunk — distinct from pos: pad slots carry
        # pos == -1 but still occupy their cache slot
        slots = jnp.arange(nc, dtype=jnp.int32) * bcp
        ctx = self._ctx(p, method, backend=backend)

        def body(carry, inp):
            cch, _ = carry
            xc, pc, sl = inp
            h, cch, _aux, _, _ = self._apply_stacks(p, xc, pc, cch,
                                                    dict(ctx, slot=sl))
            return (cch, h[:, -1, :]), None

        (cache, last_h), _ = jax.lax.scan(
            body, (cache, jnp.zeros((b, d), cfg.compute_dtype)),
            (xs, ps, slots))
        return self._readout(p, last_h[:, None, :])[:, 0], cache

    def prefill_chunk(self, p, batch: Dict, pos_start, cache: ModelCache,
                      method: Optional[str] = None,
                      backend: Optional[str] = None,
                      valid_len=None, with_obs: bool = False,
                      sel_blocks: Optional[Tuple[int, int]] = None):
        """One B_CP chunk through all stacks — the steady-state unit of
        chunked prefill for per-chunk dispatch (continuous batching / the
        production serving path; §Perf: carrying caches through a scan over
        chunks shuttles every layer's full cache per chunk, while per-chunk
        dispatch with a DONATED cache updates 128 rows in place).

        batch["tokens"]: (b, B_CP) chunk; pos_start: traced scalar, or a
        per-row (b,) vector under continuous batching (each request's chunk
        starts at its own offset).  ``valid_len`` (b,) optionally marks how
        many leading chunk tokens are real (tail chunks of a ragged batch;
        the rest get pos = -1 and are masked everywhere).
        Returns (last VALID hidden (b, d), cache); with ``with_obs=True``
        additionally returns the per-layer ``LayerObs`` aux-stats pytree
        (leaves (n_layers,)) as a third output — extra jit outputs, no host
        callbacks (the selection computation itself is unchanged).
        ``sel_blocks = (block_size, n_blocks)`` appends the prefetch-oracle
        selection-count output ((b, n_blocks) int32, summed over layers)
        after ``obs`` (same extra-jit-output pattern; orthogonal flags)."""
        cfg = self.cfg
        method = method or cfg.quoka.method
        tok = batch["tokens"]
        b, t = tok.shape
        dt = cfg.compute_dtype
        x = embed(p["embed"], tok, dt)
        s = jnp.asarray(pos_start, jnp.int32)
        offs = jnp.arange(t, dtype=jnp.int32)
        pos = (s + offs)[None].repeat(b, 0) if s.ndim == 0 \
            else s[:, None] + offs[None]
        if valid_len is not None:
            vl = jnp.asarray(valid_len, jnp.int32)
            pos = jnp.where(offs[None] < vl[:, None], pos, -1)
        if not cfg.use_rope:
            x = x + sinusoidal(pos, cfg.d_model, dt)
        from repro.sharding import ctx as shctx
        x = shctx.shard_activation(x)
        ctx = self._ctx(p, method, backend=backend)
        ctx["slot"] = s
        if with_obs:
            ctx["obs"] = True
        if sel_blocks is not None:
            ctx["selblk"] = (int(sel_blocks[0]), int(sel_blocks[1]))
        x, cache, _, obs, sel = self._apply_stacks(p, x, pos, cache, ctx)
        if valid_len is None:
            last = x[:, -1, :]
        else:
            li = jnp.clip(vl - 1, 0, t - 1)
            last = jnp.take_along_axis(x, li[:, None, None], axis=1)[:, 0, :]
        out = (last, cache)
        if with_obs:
            out = out + (obs,)
        if sel_blocks is not None:
            out = out + (sel,)
        return out if len(out) > 2 else (last, cache)

    def decode_step(self, p, tokens, pos, cache: ModelCache,
                    method: Optional[str] = None,
                    backend: Optional[str] = None,
                    with_obs: bool = False,
                    sel_blocks: Optional[Tuple[int, int]] = None):
        """One decode step.  tokens: (b,) int32; pos: scalar or (b,)
        (per-request positions under continuous batching).
        Returns (logits (b, V), cache), plus the per-layer ``LayerObs``
        pytree when ``with_obs=True`` and the (b, n_blocks) selection-count
        output when ``sel_blocks`` is set (see prefill_chunk; obs first)."""
        cfg = self.cfg
        method = method or cfg.quoka.method
        dt = cfg.compute_dtype
        b = tokens.shape[0]
        x = embed(p["embed"], tokens[:, None], dt)
        ps = jnp.asarray(pos, jnp.int32)
        pos2 = jnp.broadcast_to(ps.reshape(-1, 1), (b, 1))
        if not cfg.use_rope:
            x = x + sinusoidal(pos2, cfg.d_model, dt)
        ctx = self._ctx(p, method, backend=backend)
        ctx["slot"] = ps
        if with_obs:
            ctx["obs"] = True
        if sel_blocks is not None:
            ctx["selblk"] = (int(sel_blocks[0]), int(sel_blocks[1]))
        x, cache, _, obs, sel = self._apply_stacks(p, x, pos2, cache, ctx)
        logits = self._readout(p, x)[:, 0]
        out = (logits, cache)
        if with_obs:
            out = out + (obs,)
        if sel_blocks is not None:
            out = out + (sel,)
        return out if len(out) > 2 else (logits, cache)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
