"""Primitive NN layers (no flax on this host — explicit param pytrees).

Conventions:
  * every layer is a pair of pure functions ``<name>_init(key, ...) -> params``
    and ``<name>(params, x, ...) -> y``;
  * params are nested dicts of jnp arrays; leaves are created in fp32 and
    cast to the compute dtype at apply time by the caller's policy.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


# ----------------------------------------------------------------------------
# initialisers
# ----------------------------------------------------------------------------

def _normal(key, shape, std, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * jnp.asarray(std, dtype)


def linear_init(key, d_in: int, d_out: int, *, bias: bool = False,
                std: Optional[float] = None, dtype=jnp.float32):
    std = (1.0 / math.sqrt(d_in)) if std is None else std
    p = {"w": _normal(key, (d_in, d_out), std, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return {"emb": _normal(key, (vocab, d), 0.02, dtype)}


def embed(p, tokens, dtype):
    return p["emb"].astype(dtype)[tokens]


def unembed(p, x):
    """Tied read-out: logits = x @ emb^T (fp32 for a stable softmax/xent)."""
    return x.astype(jnp.float32) @ p["emb"].astype(jnp.float32).T


# ----------------------------------------------------------------------------
# norms
# ----------------------------------------------------------------------------

def rmsnorm_init(d: int):
    return {"g": jnp.ones((d,), jnp.float32)}


def rmsnorm(p, x, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * p["g"]).astype(dt)


def layernorm_init(d: int):
    return {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def layernorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * p["g"] + p["b"]).astype(dt)


def groupnorm(x, n_groups: int, eps: float = 1e-5):
    """Per-head group norm used by RWKV6 (no learned affine here)."""
    dt = x.dtype
    shp = x.shape
    xf = x.astype(jnp.float32).reshape(shp[:-1] + (n_groups, shp[-1] // n_groups))
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).reshape(shp).astype(dt)


# ----------------------------------------------------------------------------
# activations / MLPs
# ----------------------------------------------------------------------------

def _act(name: str, x):
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "relu2":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(name)


def mlp_init(key, d: int, d_ff: int, *, gated: bool = True, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {"up": linear_init(ks[0], d, d_ff, dtype=dtype),
         "down": linear_init(ks[1], d_ff, d, dtype=dtype)}
    if gated:
        p["gate"] = linear_init(ks[2], d, d_ff, dtype=dtype)
    return p


def mlp(p, x, act: str = "silu"):
    up = linear(p["up"], x)
    if "gate" in p:
        h = _act(act, linear(p["gate"], x)) * up
    else:
        h = _act(act, up)
    return linear(p["down"], h)


# ----------------------------------------------------------------------------
# positions
# ----------------------------------------------------------------------------

def rope(x, positions, theta: float):
    """Rotary embedding.  x: (..., T, n_heads, head_dim); positions: (..., T)."""
    dt = x.dtype
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs          # (..., T, half)
    sin = jnp.sin(ang)[..., None, :]                                 # (..., T, 1, half)
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x1 * sin + x2 * cos], axis=-1).astype(dt)


def sinusoidal(positions, d: int, dtype=jnp.float32):
    """Whisper-style sinusoidal position embedding.  positions: (..., T)."""
    half = d // 2
    freqs = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32)
                    / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ----------------------------------------------------------------------------
# misc
# ----------------------------------------------------------------------------

def cosine_sim(a, b, axis: int = -1, eps: float = 1e-8):
    """CosSim along `axis` with broadcasting; computed in fp32."""
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    num = jnp.sum(af * bf, axis=axis)
    den = jnp.linalg.norm(af, axis=axis) * jnp.linalg.norm(bf, axis=axis)
    return num / (den + eps)


def l2_normalize(x, axis: int = -1, eps: float = 1e-8):
    xf = x.astype(jnp.float32)
    return (xf / (jnp.linalg.norm(xf, axis=axis, keepdims=True) + eps)).astype(x.dtype)
