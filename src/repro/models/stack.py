"""Scanned layer stacks.

Homogeneous (or period-repeating) layers are stacked along a leading
`repeats` axis and executed with ``lax.scan`` — one compiled block body per
*period position* regardless of depth, which keeps HLO size and compile time
flat for 40-80 layer models (essential on this 1-core build host, and the
standard production pattern on TPU).

A stack is ``(period, n_repeats)``: e.g. gemma3-27b is
(5×attn_local + 1×attn) × 10 (+ a 2-layer tail stack).  Weight *sharing*
(zamba2's shared attention block) falls out naturally: the shared params are
closed over via ``ctx`` instead of being scanned.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import plan as plan_mod
from repro.models.blocks import make_block
from repro.sharding import ctx as shctx


class Stack:
    def __init__(self, cfg: ModelConfig, period: Sequence[str], repeats: int):
        self.cfg = cfg
        self.period = tuple(period)
        self.repeats = repeats
        self.blocks = [make_block(cfg, k) for k in self.period]

    # ------------------------------------------------------------------
    def init(self, key) -> Tuple:
        """Params: tuple over period positions; leaves have leading
        (repeats, ...) axis."""
        out = []
        for j, blk in enumerate(self.blocks):
            keys = jax.random.split(jax.random.fold_in(key, j), self.repeats)
            ps = [blk.init(k) for k in keys]
            out.append(jax.tree.map(lambda *ls: jnp.stack(ls), *ps))
        return tuple(out)

    def init_cache(self, batch: int, cap: int, dtype) -> Tuple:
        out = []
        for blk in self.blocks:
            spec = blk.cache_spec(batch, cap, dtype)
            out.append(jax.tree.map(
                lambda l: jnp.tile(l[None], (self.repeats,) + (1,) * l.ndim),
                spec))
        return tuple(out)

    # ------------------------------------------------------------------
    def train(self, params: Tuple, x, pos, ctx):
        """Full-sequence forward.  Returns (x, aux_loss)."""
        def body(carry, p_slice):
            h, aux = carry
            for j, blk in enumerate(self.blocks):
                h = shctx.shard_activation(h)
                h, a = blk.train(p_slice[j], h, pos, ctx)
                aux = aux + jnp.asarray(a, jnp.float32)
            return (h, aux), None

        if self.cfg.remat:
            # full recompute.  §Perf B3 measured dots_saveable policy at
            # -2% collectives / -8% flops but +74% peak memory — the wrong
            # trade at 671B scale, where HBM is the binding constraint.
            body = jax.checkpoint(body)
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   params)
        return x, aux

    def _plan_carry0(self, caches: Tuple, t: int, ctx, plan):
        """The initial cross-layer plan carry, or None when reuse is off.

        Reuse engages only when ``qcfg.reuse_interval > 1`` AND every period
        position would build the same-shaped plan (uniform geometry): a
        heterogeneous period (mixed capacities, dense-fallback layers, a
        non-selecting block) silently disables the carry and every layer
        builds its own plan — byte-identical to the reuse-off path.  An
        incoming carry from a previous stack is adopted when its shape
        matches, so reuse runs span stack boundaries."""
        qcfg = ctx.get("qcfg") if isinstance(ctx, dict) else None
        method = ctx.get("method", "full") if isinstance(ctx, dict) else "full"
        if (qcfg is None or method == "full"
                or max(1, getattr(qcfg, "reuse_interval", 1)) <= 1):
            return None
        shapes = [getattr(blk, "plan_carry_shape", None) and
                  blk.plan_carry_shape(caches[j], t, method, qcfg)
                  for j, blk in enumerate(self.blocks)]
        if shapes[0] is None or any(s != shapes[0] for s in shapes):
            return None
        if isinstance(plan, plan_mod.PlanCarry) and plan.idx.shape == shapes[0]:
            return plan
        return plan_mod.empty_carry(shapes[0])

    def apply(self, params: Tuple, x, pos, caches: Tuple, ctx, plan=None):
        """Prefill-chunk / decode forward with caches.
        Returns (x, new_caches, aux, plan, obs, sel) — ``plan`` is the
        cross-layer ``PlanCarry`` threaded through the scan when
        KV-selection reuse is on (core/plan.py), passed through untouched
        otherwise.  ``obs`` is a ``LayerObs`` pytree with (n_layers,)
        leaves in global layer order when ``ctx["obs"]`` is set, else None:
        each block leaves its per-layer stats in its ctx copy (the MoE
        aux-loss side-channel) and the scan body collects them as ys —
        seven scalars per layer, nothing like the cache-ys trap below.
        ``sel`` is the prefetch-oracle side channel: when
        ``ctx["selblk"] = (block_size, n_blocks)`` is set, the (b,
        n_blocks) int32 sum over this stack's layers of each plan's
        ``pool_block_counts`` (layers that left none — dense, recurrent —
        count zero), else None.

        Caches live in the scan CARRY and are updated through WINDOWED
        dynamic-update-slices (only the rows a chunk actually writes), not
        as scan ys.  The ys formulation shuttles every layer's full cache
        through the loop boundary per chunk — measured at 47 TB/chip for a
        32k prefill (§Perf A3) — while XLA aliases a loop-carried buffer in
        place, so this path books only the written rows.
        """
        t = x.shape[1]
        slot = ctx.get("slot")
        start = pos[0, 0] if slot is None else slot
        carry0 = self._plan_carry0(caches, t, ctx, plan)
        layer0 = int(ctx.get("layer0", 0)) if isinstance(ctx, dict) else 0
        n_period = len(self.blocks)
        obs_on = isinstance(ctx, dict) and bool(ctx.get("obs"))
        sel_on = isinstance(ctx, dict) and ctx.get("selblk") is not None

        def write_back(blk, buf_tree, new_slice, idx):
            """Windowed write of one layer's cache updates into the stacked
            buffers.  KV/latent rows: only the [start, start+t) window (mod W
            for ring buffers); recurrent states: whole (small) leaves.  XLA
            simplifies slice(DUS(orig, rows)) back to the rows, so the
            block's full returned cache never materialises."""
            s32 = jnp.asarray(start, jnp.int32)

            def upd_rows(buf, new, ring: bool):
                # buf: (R, b, cap, ...); new: (b, cap, ...)
                if t == 1 and new.shape[0] == 1:
                    # batch-1 decode: the cache shards its SEQUENCE axis
                    # (sharding/specs.py), and a windowed DUS at a traced
                    # position into a sequence-sharded buffer makes GSPMD
                    # reshard (measured +240 ms collective on zamba2
                    # long_500k); a whole-slice write keeps layouts aligned.
                    # Batched decode caches shard over BATCH instead — the
                    # windowed write below stays collective-free there.
                    return buf.at[idx].set(new.astype(buf.dtype))
                if s32.ndim == 1:
                    # per-request write offsets (continuous batching): every
                    # step-batch row writes its own [slot, slot + t) window
                    rows = jax.vmap(lambda n, s: jax.lax.dynamic_slice_in_dim(
                        n, s, t, axis=0))(new, s32)          # (b, t, ...)
                    slots = s32[:, None] + jnp.arange(t, dtype=jnp.int32)
                    bidx = jnp.arange(new.shape[0])[:, None]
                    return buf.at[idx, bidx, slots].set(rows.astype(buf.dtype))
                if ring:
                    cap = buf.shape[2]
                    slots = (s32 + jnp.arange(t, dtype=jnp.int32)) % cap
                    rows = jnp.take(new, slots, axis=1)      # (b, t, ...)
                    # two advanced indices (traced idx + slots) move the
                    # indexed axes to the front: update shape is (t, b, ...)
                    return buf.at[idx, :, slots].set(rows.swapaxes(0, 1))
                rows = jax.lax.dynamic_slice_in_dim(new, s32, t, axis=1)
                starts = (idx, jnp.zeros((), jnp.int32), s32) + \
                    tuple(jnp.zeros((), jnp.int32) for _ in range(buf.ndim - 3))
                return jax.lax.dynamic_update_slice(
                    buf, rows[None].astype(buf.dtype), starts)

            out = []
            for name in buf_tree._fields:
                b_f = getattr(buf_tree, name)
                n_f = getattr(new_slice, name)
                if b_f == () or b_f is None:
                    out.append(b_f)
                    continue
                if name in ("kv", "latent"):
                    ring = (name == "kv"
                            and getattr(blk, "window", None) is not None)
                    out.append(type(b_f)(**{
                        ln: upd_rows(getattr(b_f, ln), getattr(n_f, ln), ring)
                        for ln in b_f._fields}))
                else:   # mamba / rwkv / cross: small states, copy whole
                    out.append(jax.tree.map(
                        lambda lb, nn: lb.at[idx].set(nn), b_f, n_f))
            return type(buf_tree)(*out)

        def body(carry, xs):
            if carry0 is not None:
                h, aux, bufs, pc = carry
            else:
                h, aux, bufs = carry
                pc = None
            p_slice, idx = xs
            new_bufs = []
            obs_j, sel_j = [], []
            for j, blk in enumerate(self.blocks):
                h = shctx.shard_activation(h)
                c_slice = jax.tree.map(
                    lambda l: jax.lax.dynamic_index_in_dim(
                        l, idx, axis=0, keepdims=False), bufs[j])
                # obs/sel need a PER-LAYER ctx copy (each layer pops its own
                # "_obs"/"_selblk"); the reuse carry needs one for layer_idx
                cj = ctx if pc is None and not obs_on and not sel_on else \
                    dict(ctx, layer_idx=layer0 + idx * n_period + j)
                h, c_new, a, pc = blk.apply(p_slice[j], h, pos, c_slice, cj,
                                            plan=pc)
                if obs_on:
                    ob = cj.pop("_obs", None)
                    obs_j.append(plan_mod.nan_obs() if ob is None else ob)
                if sel_on:
                    sb = cj.pop("_selblk", None)
                    sel_j.append(jnp.zeros((h.shape[0], ctx["selblk"][1]),
                                           jnp.int32) if sb is None else sb)
                new_bufs.append(write_back(blk, bufs[j], c_new, idx))
                aux = aux + jnp.asarray(a, jnp.float32)
            out = (h, aux, tuple(new_bufs))
            ys = (jax.tree.map(lambda *ls: jnp.stack(ls), *obs_j)
                  if obs_on else None,
                  jnp.stack(sel_j) if sel_on else None)
            return (out + (pc,) if carry0 is not None else out), ys

        idxs = jnp.arange(self.repeats, dtype=jnp.int32)
        init = (x, jnp.zeros((), jnp.float32), caches)
        if carry0 is not None:
            init = init + (carry0,)
        out, ys = jax.lax.scan(body, init, (params, idxs))
        if carry0 is not None:
            x, aux, caches, plan = out
        else:
            x, aux, caches = out
        obs_ys, sel_ys = ys
        obs = None
        if obs_on:
            # ys leaves: (repeats, n_period) -> flatten to global layer
            # order within this stack (layer = idx * n_period + j)
            obs = jax.tree.map(lambda l: l.reshape(-1, *l.shape[2:]), obs_ys)
        # (repeats, n_period, b, n_blocks) -> stack total per pool block
        sel = sel_ys.sum(axis=(0, 1)) if sel_on else None
        return x, caches, aux, plan, obs, sel
