"""Mamba2 (SSD) block for the zamba2 hybrid (arXiv:2411.15242 backbone).

Same chunked-scan TPU adaptation as rwkv6.py, but the decay is a *scalar
per head per step* (state-space dual form), so the intra-chunk pairwise
tensor is only (b, H, C, C) — cheap; we use a wider sub-chunk.

Sharding note: the reference implementation fuses [z | xBC | dt] into one
in_proj; here the projections are SEPARATE params so each output axis can be
tensor-sharded cleanly (z/x/dt head-aligned over `model`, the small B/C
channels replicated) — see sharding/specs.py.  The depthwise conv is split
the same way (mathematically identical for depthwise).

State per layer: conv tail (b, d_conv-1, channels) + SSD state
(b, H, P, N) fp32.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import linear, linear_init, rmsnorm, rmsnorm_init
from repro.serving.cache import MambaCache

CHUNK = 64


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    nh = di // s.head_dim
    conv_ch = di + 2 * s.d_state       # x, B, C all pass the conv
    return di, nh, conv_ch


def mamba_init(key, cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    di, nh, _ = _dims(cfg)
    ks = jax.random.split(key, 6)
    return {
        "z_proj": linear_init(ks[0], d, di),
        "x_proj": linear_init(ks[1], d, di),
        "bc_proj": linear_init(ks[2], d, 2 * s.d_state),
        "dt_proj": linear_init(ks[3], d, nh),
        "conv_x_w": jax.random.normal(ks[4], (s.d_conv, di)) / math.sqrt(s.d_conv),
        "conv_x_b": jnp.zeros((di,)),
        "conv_bc_w": jax.random.normal(ks[5], (s.d_conv, 2 * s.d_state))
                     / math.sqrt(s.d_conv),
        "conv_bc_b": jnp.zeros((2 * s.d_state,)),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)),
        "d_skip": jnp.ones((nh,)),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(jax.random.fold_in(key, 7), (nh,),
                                       minval=math.log(1e-3),
                                       maxval=math.log(1e-1))))),
        "norm": rmsnorm_init(di),
        "out_proj": linear_init(jax.random.fold_in(key, 8), di, d),
    }


def mamba_cache_init(batch: int, cfg: ModelConfig, dtype) -> MambaCache:
    s = cfg.ssm
    di, nh, conv_ch = _dims(cfg)
    return MambaCache(
        conv=jnp.zeros((batch, s.d_conv - 1, conv_ch), dtype),
        ssd=jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
    )


def _causal_conv(xc, conv_tail, w, b):
    """Depthwise causal conv over time.  xc: (b, T, ch); conv_tail: (b, K-1, ch).
    Returns (y (b, T, ch), new_tail)."""
    kw = w.shape[0]
    full = jnp.concatenate([conv_tail.astype(xc.dtype), xc], axis=1)
    y = sum(full[:, i:i + xc.shape[1], :] * w[i].astype(xc.dtype)
            for i in range(kw))
    y = y + b.astype(xc.dtype)
    new_tail = full[:, -(kw - 1):, :] if kw > 1 else conv_tail
    return y, new_tail


def _ssd_chunked(x, dt, la, B, C, state):
    """Chunked SSD scan.

    x: (b, T, H, P) fp32; dt: (b, T, H); la: (b, T, H) log-decay <= 0;
    B, C: (b, T, N); state: (b, H, P, N) fp32.
    Returns (y (b, T, H, P), new_state).
    """
    b, t, h, p = x.shape
    c = min(CHUNK, t)
    nc = t // c
    r = lambda a: a.reshape(b, nc, c, *a.shape[2:]).swapaxes(0, 1)
    xs, dts, las, Bs, Cs = r(x), r(dt), r(la), r(B), r(C)
    tri = jnp.tril(jnp.ones((c, c), bool))                   # s <= t

    def body(S, inp):
        xc, dtc, lac, Bc, Cc = inp                            # (b,c,...)
        cum = jnp.cumsum(lac, axis=1)                         # (b,c,H) inclusive
        # intra: P[t,s] = (C_t . B_s) exp(cum_t - cum_s) dt_s , s <= t
        expo = cum[:, :, None, :] - cum[:, None, :, :]        # (b,t,s,H)
        expo = jnp.where(tri[None, :, :, None], expo, -jnp.inf)
        cb = jnp.einsum("btn,bsn->bts", Cc, Bc)               # (b,t,s)
        pm = cb[..., None] * jnp.exp(expo) * dtc[:, None, :, :]
        y_intra = jnp.einsum("btsh,bshp->bthp", pm, xc)
        # inter: y_t += (exp(cum_t) S) . C_t
        y_inter = jnp.einsum("bth,bhpn,btn->bthp", jnp.exp(cum), S, Cc)
        # state to end of chunk
        wS = jnp.exp(cum[:, -1, :])                           # (b,H)
        coef = jnp.exp(cum[:, -1:, :] - cum) * dtc            # (b,c,H)
        S_new = wS[:, :, None, None] * S + jnp.einsum(
            "bch,bchp,bcn->bhpn", coef, xc, Bc)
        return S_new, y_intra + y_inter

    state, ys = jax.lax.scan(body, state, (xs, dts, las, Bs, Cs))
    return ys.swapaxes(0, 1).reshape(b, t, h, p), state


def mamba_apply(p, x, cache: MambaCache, cfg: ModelConfig
                ) -> Tuple[jax.Array, MambaCache]:
    """One Mamba2 mixer over segment x (b, T, d) (already normed)."""
    s = cfg.ssm
    b, t, d = x.shape
    di, nh, conv_ch = _dims(cfg)
    z = linear(p["z_proj"], x)
    xr = linear(p["x_proj"], x)
    bc = linear(p["bc_proj"], x)
    dt_raw = linear(p["dt_proj"], x)
    xr, tail_x = _causal_conv(xr, cache.conv[..., :di],
                              p["conv_x_w"], p["conv_x_b"])
    bc, tail_bc = _causal_conv(bc, cache.conv[..., di:],
                               p["conv_bc_w"], p["conv_bc_b"])
    xr = jax.nn.silu(xr)
    bc = jax.nn.silu(bc)
    x_ssm = xr.astype(jnp.float32).reshape(b, t, nh, s.head_dim)
    Bm = bc[..., :s.d_state].astype(jnp.float32)
    Cm = bc[..., s.d_state:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (b,t,H)
    la = -dt * jnp.exp(p["a_log"])                                   # <= 0

    # pad to sub-chunk multiple
    c = min(CHUNK, max(t, 1))
    pad = (-t) % c
    if pad:
        pf = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        x_ssm, Bm, Cm, dt = pf(x_ssm), pf(Bm), pf(Cm), pf(dt)
        la = jnp.pad(la, ((0, 0), (0, pad), (0, 0)))   # la=0 ⇒ state kept
    y, state = _ssd_chunked(x_ssm, dt, la, Bm, Cm, cache.ssd)
    y = y[:, :t] + p["d_skip"][None, None, :, None] * x_ssm[:, :t]
    y = y.reshape(b, t, di).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    new_conv = jnp.concatenate([tail_x, tail_bc], axis=-1)
    return linear(p["out_proj"], y), MambaCache(conv=new_conv, ssd=state)
