"""RWKV6 "Finch" block — attention-free time-mix with DATA-DEPENDENT decay
(arXiv:2404.05892), plus the squared-ReLU channel-mix.

TPU adaptation (see DESIGN.md): instead of a per-token recurrence (a 4096-
iteration while-loop that starves the MXU), the segment is processed in
sub-chunks of ``CHUNK`` tokens with the intra-chunk interactions expressed as
a masked (t, s, d) einsum and the inter-chunk state carried by a short
``lax.scan`` — the GLA/chunked-scan formulation.  All exponents are pairwise
*differences* of cumulative log-decays (always <= 0), so the fp32 math never
overflows even for long segments.

State per layer: wkv (b, H, D, D) fp32, plus the token-shift carries.
QUOKA does not apply here (no KV cache) — noted in DESIGN.md.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import groupnorm, linear, linear_init
from repro.serving.cache import RWKVCache

CHUNK = 16  # intra-chunk einsum width (C*C*D working set per head)


def rwkv_init(key, cfg: ModelConfig):
    d = cfg.d_model
    hd = cfg.rwkv.head_dim
    nh = d // hd
    lora = cfg.rwkv.decay_lora
    ks = jax.random.split(key, 12)
    std = 1.0 / math.sqrt(d)
    # w0 init: spread decays across channels (faithful to RWKV init style)
    w0 = -5.0 + 8.0 * (jnp.arange(d) / max(d - 1, 1)) ** 0.7
    return {
        "tm": {  # time mix
            "mu": jnp.full((5, d), 0.5, jnp.float32),  # r,k,v,g,w shift mix
            "wr": linear_init(ks[0], d, d),
            "wk": linear_init(ks[1], d, d),
            "wv": linear_init(ks[2], d, d),
            "wg": linear_init(ks[3], d, d),
            "wo": linear_init(ks[4], d, d, std=std / math.sqrt(2 * cfg.n_layers)),
            "w0": w0,                                   # (d,) decay bias
            "wa": jax.random.normal(ks[5], (d, lora)) * 0.01,
            "wb": jax.random.normal(ks[6], (lora, d)) * 0.01,
            "u": jax.random.normal(ks[7], (nh, hd)) * 0.1,   # bonus
        },
        "cm": {  # channel mix
            "mu": jnp.full((2, d), 0.5, jnp.float32),   # k,r shift mix
            "wk": linear_init(ks[8], d, cfg.d_ff),
            "wv": linear_init(ks[9], cfg.d_ff, d, std=1.0 / math.sqrt(cfg.d_ff)),
            "wr": linear_init(ks[10], d, d),
        },
    }


def _shift_mix(x, x_prev, mu):
    """Token shift: interpolate each token with its predecessor.
    x: (b, T, d); x_prev: (b, d) carry.  Returns mixed (b, T, d) per mu row."""
    shifted = jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)
    return x + (shifted - x) * mu  # mu broadcasts (d,) or (k, 1, 1, d)


def rwkv_cache_init(batch: int, cfg: ModelConfig, dtype) -> RWKVCache:
    d = cfg.d_model
    hd = cfg.rwkv.head_dim
    nh = d // hd
    return RWKVCache(
        shift_tm=jnp.zeros((batch, d), dtype),
        shift_cm=jnp.zeros((batch, d), dtype),
        wkv=jnp.zeros((batch, nh, hd, hd), jnp.float32),
    )


def _time_mix_chunked(r, k, v, lw, u, state):
    """Chunked linear-attention recurrence.

    r,k,v,lw: (b, T, H, D) fp32, lw = log-decay <= 0; u: (H, D);
    state: (b, H, D, D).  T must be a multiple of the sub-chunk (padded by
    caller).  Returns (out (b,T,H,D), new_state).
    """
    b, t, h, d = r.shape
    c = min(CHUNK, t)
    n = t // c
    rs = r.reshape(b, n, c, h, d).transpose(1, 0, 3, 2, 4)   # (n,b,h,c,d)
    ks_ = k.reshape(b, n, c, h, d).transpose(1, 0, 3, 2, 4)
    vs = v.reshape(b, n, c, h, d).transpose(1, 0, 3, 2, 4)
    ws = lw.reshape(b, n, c, h, d).transpose(1, 0, 3, 2, 4)

    tri_lo = jnp.tril(jnp.ones((c, c), bool), k=-1)          # s < t

    def body(S, xs):
        rc, kc, vc, wc = xs                                  # (b,h,c,d)
        cum = jnp.cumsum(wc, axis=2)                         # inclusive
        ecum = cum - wc                                      # exclusive
        # intra-chunk pairwise (t,s,d) exponent differences (<= 0 for s<t)
        expo = ecum[:, :, :, None, :] - cum[:, :, None, :, :]   # (b,h,t,s,d)
        expo = jnp.where(tri_lo[None, None, :, :, None], expo, -jnp.inf)
        pmat = jnp.einsum("bhtd,bhsd,bhtsd->bhts", rc, kc, jnp.exp(expo))
        diag = jnp.einsum("bhtd,bhtd,hd->bht", rc, kc,
                          u.astype(jnp.float32))
        pmat = pmat + jnp.eye(c)[None, None] * diag[:, :, :, None]
        o_intra = jnp.einsum("bhts,bhsd->bhtd", pmat, vc)
        o_inter = jnp.einsum("bhtd,bhde->bhte", rc * jnp.exp(ecum), S)
        # state to end of chunk
        dec_all = jnp.exp(cum[:, :, -1, :])                  # (b,h,d)
        kd = kc * jnp.exp(cum[:, :, -1:, :] - cum)           # (b,h,c,d)
        S_new = dec_all[..., None] * S + jnp.einsum("bhcd,bhce->bhde", kd, vc)
        return S_new, o_intra + o_inter

    state, outs = jax.lax.scan(body, state, (rs, ks_, vs, ws))
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, t, h, d)
    return out, state


def time_mix(p, x, shift_prev, wkv_state, cfg: ModelConfig):
    """p = params['tm']; x: (b, T, d) (already normed).  Returns
    (y (b,T,d), new_shift (b,d), new_state)."""
    b, t, d = x.shape
    hd = cfg.rwkv.head_dim
    nh = d // hd
    mu = p["mu"]
    xr = _shift_mix(x, shift_prev, mu[0])
    xk = _shift_mix(x, shift_prev, mu[1])
    xv = _shift_mix(x, shift_prev, mu[2])
    xg = _shift_mix(x, shift_prev, mu[3])
    xw = _shift_mix(x, shift_prev, mu[4])

    r = linear(p["wr"], xr).astype(jnp.float32)
    k = linear(p["wk"], xk).astype(jnp.float32)
    v = linear(p["wv"], xv).astype(jnp.float32)
    g = linear(p["wg"], xg)
    # data-dependent decay (the Finch headline feature)
    ww = p["w0"] + jnp.tanh(xw.astype(jnp.float32) @ p["wa"]) @ p["wb"]
    lw = -jnp.exp(ww)                                        # log decay <= 0

    # pad T to a multiple of CHUNK
    c = min(CHUNK, max(t, 1))
    pad = (-t) % c
    if pad:
        zf = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        r, k, v = zf(r), zf(k), zf(v)
        lw = jnp.pad(lw, ((0, 0), (0, pad), (0, 0)))         # lw=0 ⇒ decay 1
    rh = r.reshape(b, -1, nh, hd)
    kh = k.reshape(b, -1, nh, hd)
    vh = v.reshape(b, -1, nh, hd)
    wh = lw.reshape(b, -1, nh, hd)
    out, state = _time_mix_chunked(rh, kh, vh, wh,
                                   p["u"], wkv_state.astype(jnp.float32))
    out = out[:, :t].reshape(b, t, d)
    y = groupnorm(out, nh).astype(x.dtype) * jax.nn.silu(g)
    y = linear(p["wo"], y)
    return y, x[:, -1, :], state


def channel_mix(p, x, shift_prev):
    """p = params['cm']; x: (b, T, d) (already normed)."""
    xk = _shift_mix(x, shift_prev, p["mu"][0])
    xr = _shift_mix(x, shift_prev, p["mu"][1])
    k = jax.nn.relu(linear(p["wk"], xk))
    k = k * k
    return jax.nn.sigmoid(linear(p["wr"], xr)) * linear(p["wv"], k), x[:, -1, :]
