"""Flat-npz checkpointing (no orbax offline).

Param pytrees are flattened to '/'-joined key paths; restore rebuilds into a
caller-provided template (shape/dtype checked), so it round-trips through
optimizer state and arbitrary NamedTuple caches too.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(path: str, tree: Any, meta: Dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path, **flat)
    if meta is not None:
        with open(path + ".meta.json", "w") as f:
            json.dump(meta, f, indent=2)


def restore(path: str, template: Any) -> Any:
    """Restore into the structure of `template` (shape/dtype validated)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)
    flat_t = _flatten(template)
    if set(data.files) != set(flat_t):
        missing = set(flat_t) - set(data.files)
        extra = set(data.files) - set(flat_t)
        raise ValueError(f"checkpoint mismatch: missing={missing} extra={extra}")
    leaves_t, treedef = jax.tree_util.tree_flatten(template)
    keys = [  # same order as template flattening
        k for k, _ in sorted(flat_t.items())]
    # rebuild by path order of tree_flatten_with_path (stable)
    path_leaves = jax.tree_util.tree_flatten_with_path(template)[0]
    new_leaves = []
    for path_, leaf in path_leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path_)
        arr = data[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        new_leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    return treedef.unflatten(new_leaves)


def load_meta(path: str) -> Dict:
    with open(path + ".meta.json") as f:
        return json.load(f)
