"""Training loop: TrainState + jitted train_step + a simple driver.

The same ``make_train_step`` is what launch/dryrun.py lowers against the
production mesh (with shardings attached), so the loop here and the dry-run
exercise identical compute graphs.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Iterable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.training import optimizer as opt


class TrainState(NamedTuple):
    params: dict
    opt: opt.OptState


def init_state(model: Model, key) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt=opt.init(params))


def make_train_step(model: Model, ocfg: opt.OptimizerConfig
                    ) -> Callable[[TrainState, Dict], tuple]:
    """Returns train_step(state, batch) -> (state, metrics)."""

    def train_step(state: TrainState, batch: Dict):
        def loss_fn(p):
            return model.loss(p, batch)
        loss, grads = jax.value_and_grad(loss_fn)(state.params)
        params, ostate, metrics = opt.apply_updates(
            state.params, grads, state.opt, ocfg)
        metrics["loss"] = loss
        return TrainState(params=params, opt=ostate), metrics

    return train_step


def train(model: Model, batches: Iterable[Dict], *,
          ocfg: Optional[opt.OptimizerConfig] = None,
          key=None, steps: Optional[int] = None,
          log_every: int = 20, state: Optional[TrainState] = None,
          callback=None):
    """Simple synchronous driver (CPU smoke / examples)."""
    ocfg = ocfg or opt.OptimizerConfig()
    key = key if key is not None else jax.random.PRNGKey(0)
    state = state or init_state(model, key)
    step_fn = jax.jit(make_train_step(model, ocfg))
    t0 = time.time()
    hist = []
    for i, batch in enumerate(batches):
        if steps is not None and i >= steps:
            break
        state, m = step_fn(state, batch)
        if i % log_every == 0 or (steps and i == steps - 1):
            loss = float(m["loss"])
            hist.append((i, loss))
            print(f"step {i:5d}  loss {loss:7.4f}  "
                  f"gnorm {float(m['grad_norm']):8.3f}  "
                  f"lr {float(m['lr']):.2e}  {time.time()-t0:6.1f}s")
        if callback is not None:
            callback(i, state, m)
    return state, hist
