"""AdamW + warmup-cosine schedule + global-norm clipping (no optax on this
host — ~the optax semantics, validated by tests/test_training.py)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0


class OptState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def schedule(cfg: OptimizerConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def init(params) -> OptState:
    # moments inherit the param dtype: fp32 training keeps fp32 moments; the
    # bf16 multi-pod dry-run keeps bf16 moments (3x param bytes total, the
    # realistic memory budget for a 671B model on a v5e pod)
    zeros = lambda t: jax.tree.map(jnp.zeros_like, t)
    return OptState(step=jnp.zeros((), jnp.int32),
                    mu=zeros(params), nu=zeros(params))


def _decay_mask(path_leaf) -> bool:
    """Decay matrices only — skip norms/biases/scalars (standard AdamW)."""
    return path_leaf.ndim >= 2


def apply_updates(params, grads, state: OptState, cfg: OptimizerConfig):
    """Returns (new_params, new_state, metrics dict)."""
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
        vf = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
        mhat = mf / b1c
        vhat = vf / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if _decay_mask(p):
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, mf.astype(m.dtype), vf.astype(v.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, OptState(step=step, mu=new_m, nu=new_v), \
        {"lr": lr, "grad_norm": gnorm}
