"""Dense (masked) attention reference with GQA, used everywhere the Pallas
kernel is not (CPU smoke tests, XLA path, and as the oracle for kernels).

Layout convention: activations are (batch, time, heads, head_dim) — "BTHD".
Masks are derived from *position arrays* rather than offsets: every cached
key carries its absolute position (or -1 when the slot is empty), so causal,
sliding-window and gathered/selected caches all use the same code path.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.sharding import ctx as shctx

NEG_INF = -1e30


def position_mask(q_pos, k_pos, *, causal: bool = True,
                  window: Optional[int] = None):
    """Boolean attention mask from absolute positions.

    q_pos: (b, tq) int32; k_pos: (b, tk) int32, -1 marks an invalid slot.
    Returns (b, 1, tq, tk) bool (True = attend).
    """
    q = q_pos[:, :, None]            # (b, tq, 1)
    k = k_pos[:, None, :]            # (b, 1, tk)
    m = k >= 0
    if causal:
        m = m & (k <= q)
    if window is not None:
        m = m & (k > q - window)
    return m[:, None, :, :]


def dense_attention(q, k, v, mask=None, *, scale: Optional[float] = None,
                    soft_cap: Optional[float] = None):
    """Masked softmax attention with GQA.

    q: (b, tq, n_q, d); k, v: (b, tk, n_kv, d); n_q % n_kv == 0.
    mask: bool (True = attend), shape (b, H, tq, tk) with H in {1, n_kv, n_q}.
    Returns (b, tq, n_q, dv).

    GQA uses the FLAT-HEAD form (kv repeated to n_q heads) rather than a
    (n_kv, group) reshape: the flat head axis tensor-shards over `model`
    even when n_kv < |model| (e.g. granite 32H/8KV on a 16-way axis), which
    the grouped form cannot express without resharding every layer.
    """
    b, tq, n_q, d = q.shape
    _, tk, n_kv, _ = k.shape
    group = n_q // n_kv
    scale = (d ** -0.5) if scale is None else scale
    kr = jnp.repeat(k, group, axis=2) if group > 1 else k
    vr = jnp.repeat(v, group, axis=2) if group > 1 else v

    logits = jnp.einsum("bthd,bshd->bhts", q, kr,
                        preferred_element_type=jnp.float32) * scale
    if soft_cap is not None:
        logits = soft_cap * jnp.tanh(logits / soft_cap)
    if mask is not None:
        h = mask.shape[1]
        if h == n_kv and n_kv not in (1, n_q):
            mask = jnp.repeat(mask, group, axis=1)
        logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    if mask is not None:
        # fully-masked rows: softmax over NEG_INF is uniform garbage — zero
        # them, matching blocked_attention and the Pallas kernel (l == 0)
        probs = jnp.where(mask.any(-1, keepdims=True), probs, 0.0)
    out = jnp.einsum("bhts,bshd->bthd", probs.astype(vr.dtype), vr)
    return out


BLOCKED_THRESHOLD = 2048   # switch to online-softmax streaming above this
BLOCK_K = 1024


def blocked_attention(q, k, v, q_pos, k_pos, *, causal=True, window=None,
                      scale=None, block_k: int = BLOCK_K):
    """Memory-efficient attention: lax.scan over key blocks with an online
    softmax (Rabe & Staats / flash semantics) in pure XLA ops.

    This is the compile-anywhere twin of kernels/flash_attention.py — the
    (tq × tk) score matrix is never materialised, so the HBM roofline term
    stays linear in tk.  The key-block loop body is rematerialised
    (jax.checkpoint), so the backward pass recomputes block scores instead
    of saving them.
    """
    b, tq, n_q, d = q.shape
    tk, n_kv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    group = n_q // n_kv
    scale = (d ** -0.5) if scale is None else scale
    block_k = min(block_k, tk)
    pad = (-tk) % block_k
    if pad:
        zf = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        k, v = zf(k), zf(v)
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
    nb = k.shape[1] // block_k
    ks = k.reshape(b, nb, block_k, n_kv, d).swapaxes(0, 1)
    vs = v.reshape(b, nb, block_k, n_kv, dv).swapaxes(0, 1)
    ps = k_pos.reshape(b, nb, block_k).swapaxes(0, 1)
    qf = shctx.shard_heads(q.astype(jnp.float32) * scale, 2)  # (b,tq,h,d)

    def body(carry, xs):
        m_prev, l_prev, acc = carry
        kb, vb, pb = xs
        if group > 1:
            kb = jnp.repeat(kb, group, axis=2)
            vb = jnp.repeat(vb, group, axis=2)
        s = jnp.einsum("bthd,bshd->bhts", qf, kb.astype(jnp.float32))
        mask = position_mask(q_pos, pb, causal=causal, window=window)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhts,bshd->bhtd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc), None

    init = (shctx.shard_heads(jnp.full((b, n_q, tq), NEG_INF, jnp.float32), 1),
            shctx.shard_heads(jnp.zeros((b, n_q, tq), jnp.float32), 1),
            shctx.shard_heads(jnp.zeros((b, n_q, tq, dv), jnp.float32), 1))
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(body), init, (ks, vs, ps))
    safe = jnp.where(l > 0, l, 1.0)
    out = jnp.where((l > 0)[..., None], acc / safe[..., None], 0.0)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def attention_with_positions(q, k, v, q_pos, k_pos, *, causal=True,
                             window=None, soft_cap=None):
    tk = k.shape[1]
    if soft_cap is None and tk > BLOCKED_THRESHOLD:
        return blocked_attention(q, k, v, q_pos, k_pos, causal=causal,
                                 window=window)
    mask = position_mask(q_pos, k_pos, causal=causal, window=window)
    return dense_attention(q, k, v, mask, soft_cap=soft_cap)
