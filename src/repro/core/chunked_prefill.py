"""Standalone chunked-prefill sparse attention (paper Algorithm 2) at the
single-attention-layer level.

Given the full-sequence Q, K, V of one layer, simulate chunked prefill with
any selection method and return the attention outputs for every position.
This is the apples-to-apples harness behind the accuracy-proxy benchmarks
(paper Tables 1/3 proxies) and the equivalence property tests
(budget >= T  ==>  output == dense causal attention).

The full model path lives in models/model.py::Model.prefill; this module is
deliberately model-free.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import QuokaConfig
from repro.core import plan as plan_mod
from repro.core.attention import attention_with_positions
from repro.kernels import ops as kops


def dense_causal_reference(q, k, v):
    """Oracle: full causal attention.  q (b,T,h,d), k/v (b,T,n_kv,d)."""
    b, t = q.shape[:2]
    pos = jnp.arange(t, dtype=jnp.int32)[None].repeat(b, 0)
    return attention_with_positions(q, k, v, pos, pos, causal=True)


def chunked_sparse_attention(q, k, v, cfg: QuokaConfig,
                             method: Optional[str] = None,
                             unroll: bool = False,
                             backend: Optional[str] = None):
    """Chunked prefill with per-chunk KV selection.

    q: (b, T, h, d); k, v: (b, T, n_kv, d); T % cfg.chunk_size == 0.
    ``backend`` explicitly pins the kernel backend (outranks the
    REPRO_BACKEND env var and ``cfg.backend``; see kernels/ops.py).
    Returns (b, T, h, d) attention outputs (softmax over the selected set —
    the quantity eq. (4) asks ``f`` to preserve).
    """
    import dataclasses

    method = method or cfg.method
    b, t, h, d = q.shape
    n_kv = k.shape[2]
    bcp = min(cfg.chunk_size, t)
    assert t % bcp == 0
    nc = t // bcp
    pos_all = jnp.arange(t, dtype=jnp.int32)[None].repeat(b, 0)

    if method == "full":
        return dense_causal_reference(q, k, v)

    # resolve once and bake into cfg so the scoring stage (inside
    # sel_mod.select) dispatches consistently with the attention stage
    backend = kops.resolve_backend(backend, cfg)
    cfg = dataclasses.replace(cfg, backend=backend)
    qs = q.reshape(b, nc, bcp, h, d).swapaxes(0, 1)
    ks = k.reshape(b, nc, bcp, n_kv, d).swapaxes(0, 1)
    vs = v.reshape(b, nc, bcp, n_kv, d).swapaxes(0, 1)
    ps = pos_all.reshape(b, nc, bcp).swapaxes(0, 1)

    fused = plan_mod.fused_route(cfg, method, k)

    def one_chunk(i, qc, kc, vc, pc):
        start = pc[0, 0]
        if fused:
            # gather-free path: build the plan and attend straight through
            # its block ids (kernels/selected_attention.py) — the chunk KV
            # is read from the full cache view at [start, start + B_CP)
            pln = plan_mod.build(method, qc, k, pos_all, start, cfg)
            return kops.selected_attention(
                qc, k, v, pos_all, pln.idx, start,
                granularity=plan_mod.grid(cfg), backend=backend, cfg=cfg)
        # the staged plan pipeline (score -> select -> materialize); block
        # plans include boundary-straddling blocks whole and re-mask their
        # not-yet-prior tokens inside materialize
        sel = plan_mod.select(method, qc, k, v, pos_all, start, cfg)
        # [selected budget | chunk] layout: the budget is an unconditioned
        # prefix (every gathered key is strictly before the chunk by
        # construction), the chunk is causal w.r.t. chunk-local indices —
        # exactly the flash kernel's static `boundary` mask, with budget
        # padding masked via per-KV-head k_valid (sel.pos == -1).
        k_cat = jnp.concatenate([sel.k, kc], axis=1)
        v_cat = jnp.concatenate([sel.v, vc], axis=1)
        k_valid = jnp.concatenate(
            [sel.pos >= 0, jnp.ones((b, n_kv, bcp), bool)], axis=-1)
        return kops.attention(qc, k_cat, v_cat, k_valid, causal=True,
                              boundary=sel.pos.shape[-1], backend=backend)

    if unroll:
        outs = [one_chunk(i, qs[i], ks[i], vs[i], ps[i]) for i in range(nc)]
        out = jnp.stack(outs)
    else:
        def body(_, inp):
            i, qc, kc, vc, pc = inp
            return None, one_chunk(i, qc, kc, vc, pc)
        _, out = jax.lax.scan(
            body, None, (jnp.arange(nc), qs, ks, vs, ps))
    return out.swapaxes(0, 1).reshape(b, t, h, d)


def output_error(q, k, v, cfg: QuokaConfig, method: str) -> jax.Array:
    """Relative L2 error vs the dense-causal oracle (paper eq. (4))."""
    ref = dense_causal_reference(q, k, v)
    out = chunked_sparse_attention(q, k, v, cfg, method)
    num = jnp.linalg.norm((out - ref).astype(jnp.float32))
    den = jnp.linalg.norm(ref.astype(jnp.float32)) + 1e-9
    return num / den


def _oracle_probs(q, k, start, pos_all):
    b, t, h, d = q.shape
    n_kv = k.shape[2]
    qc = q[:, start:]
    mask = (pos_all[:, None, None, :] < start)
    kr = jnp.repeat(k, h // n_kv, axis=2)
    logits = jnp.einsum("bthd,bshd->bhts", qc.astype(jnp.float32),
                        kr.astype(jnp.float32)) / jnp.sqrt(float(d))
    logits = jnp.where(mask, logits, -1e30)
    return jax.nn.softmax(logits, axis=-1)          # (b, h, chunk, T)


def key_recall(q, k, v, cfg: QuokaConfig, method: str,
               oracle: str = "max") -> jax.Array:
    """Fraction of the oracle's true top-B keys that the method selects
    (last chunk, the hardest selection).

    oracle="max": per-key criticality = max over chunk queries of the
    softmax prob — 'is this key decisive for ANY query', the NIAH/RULER
    criterion and eq-(4)'s worst case.  oracle="mean": summed mass (biased
    toward what mean-aggregating scorers compute; reported for contrast)."""
    b, t, h, d = q.shape
    n_kv = k.shape[2]
    bcp = min(cfg.chunk_size, t)
    pos_all = jnp.arange(t, dtype=jnp.int32)[None].repeat(b, 0)
    start = t - bcp
    sel = plan_mod.select(method, q[:, start:], k, v, pos_all,
                          jnp.asarray(start), cfg)
    probs = _oracle_probs(q, k, start, pos_all)
    agg = probs.max(axis=2) if oracle == "max" else probs.sum(axis=2)
    mass = agg.reshape(b, n_kv, h // n_kv, t).max(axis=2) if oracle == "max" \
        else agg.reshape(b, n_kv, h // n_kv, t).mean(axis=2)
    budget = sel.pos.shape[-1]
    _, true_top = jax.lax.top_k(mass, budget)                # (b, n_kv, B)
    sel_pos = sel.pos
    hit = (sel_pos[..., :, None] == true_top[..., None, :]).any(-1)
    valid = sel_pos >= 0
    return (hit & valid).sum() / true_top.size


def critical_key_recall(q, k, v, cfg: QuokaConfig, method: str,
                        tau: float = 0.08) -> jax.Array:
    """Recall over CRITICAL keys only: keys that receive >= tau softmax prob
    from at least one chunk query (the needle criterion).  Uncritical keys
    are excluded from the denominator, so diffuse bulk mass cannot reward a
    selector — this is the direct NIAH-mechanism proxy."""
    b, t, h, d = q.shape
    n_kv = k.shape[2]
    bcp = min(cfg.chunk_size, t)
    pos_all = jnp.arange(t, dtype=jnp.int32)[None].repeat(b, 0)
    start = t - bcp
    sel = plan_mod.select(method, q[:, start:], k, v, pos_all,
                          jnp.asarray(start), cfg)
    probs = _oracle_probs(q, k, start, pos_all)              # (b,h,c,T)
    crit = probs.max(axis=2).reshape(b, n_kv, h // n_kv, t).max(axis=2) >= tau
    sel_mask = jnp.zeros((b, n_kv, t), bool)
    bidx = jnp.arange(b)[:, None, None]
    hidx = jnp.arange(n_kv)[None, :, None]
    safe_idx = jnp.clip(sel.idx, 0, t - 1)
    sel_mask = sel_mask.at[bidx, hidx, safe_idx].set(sel.idx >= 0)
    hits = (crit & sel_mask).sum()
    return hits / jnp.maximum(crit.sum(), 1)
