"""SelectionPlan — the single KV-selection code path.

Selection used to be smeared across three call sites (``quoka_select``,
``selection.select`` and the per-block gather logic); this module replaces
all of them with one explicit three-stage pipeline:

    scores = plan_scores(method, q, k, key_pos, chunk_start, cfg)   # stage 1
    plan   = plan_from_scores(scores, key_pos, cfg, budget)         # stage 2
    sel    = materialize(plan, k, v, key_pos, chunk_start, cfg)     # stage 3

``build`` fuses stages 1+2 (including the tensor-parallel T-local fast
path, which produces plan indices directly); ``select`` fuses all three.

A plan is *just indices* — cheap to carry, compare and reuse:

  * granularity 1 (default): ``idx`` is (b, n_kv, B) per-head token slots,
    exactly the paper's Algorithm 1 top-k (bit-identical to the legacy
    token path, including sink protection and tie order).
  * granularity g > 1: ``idx`` is (b, B//g) BLOCK ids on the fixed g-token
    selection grid, shared across KV heads (CompactAttention-style).  A
    block's score is the max of its token scores over all heads, so the
    union of per-head winners is covered; blocks straddling the chunk
    boundary are selected whole and their not-yet-prior tokens re-masked at
    materialize time (the "block-union across chunk boundaries" rule).
    Setting g to the paged pool's block size makes a plan a *block-table
    sub-view*: materialize gathers whole (g, n_kv, d) slabs — XLA lowers it
    to contiguous block slices (slice size g on the token axis), never a
    per-token gather (asserted by tests/test_selection_plan.py on the HLO).

Cross-layer reuse (``QuokaConfig.reuse_interval`` / ``correction_layers``)
threads a ``PlanCarry`` through the layer scan (models/stack.py): layer L
builds, layers L+1..L+s-1 reuse, correction layers force a rebuild.
``refresh`` is the per-layer decision point.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import QuokaConfig
from repro.core import quoka as qk
from repro.core import selection as sel_scores
from repro.core.attention import NEG_INF
from repro.core.quoka import Selected, prior_context_valid
from repro.kernels import ops as kops
from repro.sharding import ctx as shctx


class SelectionPlan(NamedTuple):
    """Block/token-granular top-k indices over a KV cache view.

    idx: int32, -1 marks padding (fewer selectable slots than the budget).
      granularity == 1 -> (b, n_kv, B) token slots per KV head;
      granularity  > 1 -> (b, B//g) grid block ids shared across heads.
    """
    idx: jax.Array


class PlanCarry(NamedTuple):
    """Scan-carried plan state for cross-layer reuse: the last built plan's
    indices plus a traced validity flag (False until the first build)."""
    idx: jax.Array
    valid: jax.Array         # () bool


class LayerObs(NamedTuple):
    """Per-layer in-jit selection telemetry — seven device SCALARS (f32),
    cheap enough to ride out of ``jax.jit`` as extra outputs (the aux-stats
    pytree contract; no host callbacks on the hot path).

    NaN means "not applicable": non-attention layers are all-NaN; dense
    layers have no budget/sketch; a layer that REUSED a carried plan has a
    NaN sketch (scores were never computed — the sketch is a ``lax.cond``
    output and only the build branch produces one).

    sel_tokens    selected valid KV tokens, mean over batch & KV heads
    ctx_tokens    selectable prior-context tokens, mean over batch
                  (sel_tokens / ctx_tokens is the live selected-KV fraction
                  — the paper's "88% fewer key-value pairs" axis)
    budget_tokens the resolved grid-aligned B_SA (static, as f32)
    refreshed     1.0 if this layer BUILT a plan, 0.0 if it reused one
    score_lo/score_mean/score_hi
                  sketch of the raw stage-1 score distribution over valid
                  slots, taken BEFORE sink +inf stamping
    """
    sel_tokens: jax.Array
    ctx_tokens: jax.Array
    budget_tokens: jax.Array
    refreshed: jax.Array
    score_lo: jax.Array
    score_mean: jax.Array
    score_hi: jax.Array


# ----------------------------------------------------------------------------
# grid helpers — the ONE place budgets meet the selection grid
# ----------------------------------------------------------------------------

def grid(cfg: QuokaConfig) -> int:
    """Static selection granularity in tokens (>= 1)."""
    return max(1, int(getattr(cfg, "granularity", 1)))


# the one grid-flooring implementation lives next to resolve_budget
floor_to_grid = sel_scores.floor_to_grid


def resolve_budget(cfg: QuokaConfig, context_len: int) -> int:
    """Effective grid-aligned B_SA for a context length, clamped to the
    view — the single budget-resolution entry point for plan callers
    (selection.resolve_budget already grid-floors; callers must not
    re-round)."""
    return floor_to_grid(min(sel_scores.resolve_budget(cfg, context_len),
                             context_len), grid(cfg))


def plan_idx_shape(cfg: QuokaConfig, b: int, n_kv: int, t: int,
                   budget: Optional[int] = None):
    """Static shape of ``SelectionPlan.idx`` for a (b, T, n_kv, d) cache —
    what a scan carry must be allocated as (see models/stack.py)."""
    g = grid(cfg)
    bud = floor_to_grid(min(budget or sel_scores.resolve_budget(cfg, t), t),
                        g)
    return (b, n_kv, bud) if g == 1 else (b, bud // g)


# ----------------------------------------------------------------------------
# stage 1: score
# ----------------------------------------------------------------------------

def plan_scores(method: str, q, k, key_pos, chunk_start, cfg: QuokaConfig,
                q_valid: Optional[jax.Array] = None) -> jax.Array:
    """Per-token relevance scores (b, n_kv, T) fp32, NEG_INF on invalid
    slots, for any scoring method.  ``q_valid`` (b, t) masks ragged-tail /
    pad query rows out of quoka's chunk statistics (the baselines keep
    their published scoring definitions and ignore it)."""
    with jax.named_scope("plan_scores"):
        valid = prior_context_valid(key_pos, chunk_start)
        if method == "quoka":
            q = qk.sanitize_queries(q, q_valid)
            qs = qk.subselect_queries(q, cfg.n_queries, n_kv=k.shape[2],
                                      q_valid=q_valid)
            return qk.quoka_scores(qs, k, valid, cfg)
        return sel_scores.compute_scores(method, q, k, valid, cfg)


# ----------------------------------------------------------------------------
# stage 2: select (top-k on the grid)
# ----------------------------------------------------------------------------

def plan_from_scores(scores: jax.Array, key_pos: jax.Array,
                     cfg: QuokaConfig,
                     budget: Optional[int] = None) -> SelectionPlan:
    """Top-k of token scores on the selection grid (Algorithm 1 line 11).

    scores: (b, n_kv, T) fp32 with NEG_INF on invalid slots; key_pos (b, T).
    Sink protection first force-keeps the ``keep_first`` earliest real
    tokens (their blocks, at g > 1) by stamping +inf onto valid slots.
    """
    b, n_kv, t = scores.shape
    g = grid(cfg)
    budget = floor_to_grid(min(budget or sel_scores.resolve_budget(cfg, t),
                               t), g)
    if cfg.keep_first:
        sink = (key_pos >= 0) & (key_pos < cfg.keep_first)       # (b, T)
        scores = jnp.where(sink[:, None, :] & (scores > NEG_INF / 2),
                           jnp.inf, scores)
    if g == 1:
        top_s, top_i = jax.lax.top_k(scores, budget)             # (b,n_kv,B)
        good = top_s > NEG_INF / 2
        return SelectionPlan(idx=jnp.where(good, top_i, -1))
    if t % g:
        raise ValueError(
            f"selection granularity {g} must divide the cache view length "
            f"{t} (align granularity with the pool block size / B_CP)")
    # block score = max over the g tokens AND over KV heads: heads share
    # one plan (physical pool blocks hold every head's rows — a per-head
    # block plan could not be a contiguous sub-view of the block table)
    sb = scores.reshape(b, n_kv, t // g, g).max(axis=3).max(axis=1)
    top_s, top_i = jax.lax.top_k(sb, budget // g)                # (b, NB)
    good = top_s > NEG_INF / 2
    return SelectionPlan(idx=jnp.where(good, top_i, -1))


# ----------------------------------------------------------------------------
# stage 3: materialize (contiguous gather)
# ----------------------------------------------------------------------------

def materialize(plan: SelectionPlan, k, v, key_pos, chunk_start,
                cfg: QuokaConfig) -> Selected:
    """Gather a plan's KV budget from a cache view into a dense ``Selected``.

    k, v: (b, T, n_kv, d); key_pos: (b, T).  Validity is re-derived HERE
    (``prior_context_valid``), not trusted from build time: block-granular
    plans include boundary-straddling blocks whole and reused plans may be
    consumed under a different query chunk, so per-token selectability is a
    materialize-time property.  Tokens that are not selectable get
    ``pos == -1`` (budget padding), which downstream attention masks.

    At granularity g > 1 the gather moves whole (g, n_kv, d) slabs via a
    block-axis ``take_along_axis`` — XLA lowers this to a gather whose
    slice sizes span the full block extent (contiguous dynamic-slices over
    blocks, no per-token gather), the property the paged serving path
    relies on and the HLO suite asserts.
    """
    b, t, n_kv, d = k.shape
    g = grid(cfg)
    with jax.named_scope("plan_materialize"):
        return _materialize(plan, k, v, key_pos, chunk_start, cfg, b, t,
                            n_kv, d, g)


def _materialize(plan, k, v, key_pos, chunk_start, cfg, b, t, n_kv, d, g):
    valid = prior_context_valid(key_pos, chunk_start)            # (b, T)
    if g == 1:
        top_i = plan.idx                                         # (b,n_kv,B)
        safe = jnp.maximum(top_i, 0)
        # gather along the TIME axis directly — transposing the K/V caches
        # first would materialise a full-cache copy per chunk (§Perf A5)
        idx_t = safe.transpose(0, 2, 1)[..., None]               # (b,B,n_kv,1)
        k_sel = jnp.take_along_axis(k, idx_t, axis=1)            # (b,B,n_kv,d)
        v_sel = jnp.take_along_axis(v, idx_t, axis=1)
        shape = top_i.shape[:2] + (t,)
        pos = jnp.take_along_axis(
            jnp.broadcast_to(key_pos[:, None, :], shape), safe, axis=2)
        ok = jnp.take_along_axis(
            jnp.broadcast_to(valid[:, None, :], shape), safe, axis=2)
        good = (top_i >= 0) & ok
        return Selected(k=k_sel, v=v_sel, pos=jnp.where(good, pos, -1),
                        idx=jnp.where(good, top_i, -1))
    nb = plan.idx.shape[1]
    blocks = jnp.maximum(plan.idx, 0)                            # (b, NB)
    kb = k.reshape(b, t // g, g, n_kv, d)
    ib = blocks[:, :, None, None, None]
    k_sel = jnp.take_along_axis(kb, ib, axis=1).reshape(b, nb * g, n_kv, d)
    v_sel = jnp.take_along_axis(v.reshape(b, t // g, g, n_kv, d), ib,
                                axis=1).reshape(b, nb * g, n_kv, d)
    pos_sel = jnp.take_along_axis(key_pos.reshape(b, t // g, g),
                                  blocks[:, :, None], axis=1)    # (b, NB, g)
    ok_sel = jnp.take_along_axis(valid.reshape(b, t // g, g),
                                 blocks[:, :, None], axis=1)
    good = ok_sel & (plan.idx >= 0)[:, :, None]
    pos_flat = jnp.where(good, pos_sel, -1).reshape(b, nb * g)
    slot = blocks[:, :, None] * g + jnp.arange(g, dtype=jnp.int32)
    idx_flat = jnp.where(good, slot, -1).reshape(b, nb * g)
    # heads share the plan: broadcast the per-token metadata to the
    # Selected contract's per-head layout
    return Selected(
        k=k_sel, v=v_sel,
        pos=jnp.broadcast_to(pos_flat[:, None, :], (b, n_kv, nb * g)),
        idx=jnp.broadcast_to(idx_flat[:, None, :], (b, n_kv, nb * g)))


# ----------------------------------------------------------------------------
# fused entry points
# ----------------------------------------------------------------------------

def build(method: str, q, k, key_pos, chunk_start, cfg: QuokaConfig,
          budget: Optional[int] = None,
          q_valid: Optional[jax.Array] = None) -> SelectionPlan:
    """Stages 1+2: score the cache view and plan the top-k budget.

    For quoka under an active tensor-parallel sharding policy with an
    indivisible KV-head axis, scoring + candidate top-k run T-local per
    shard (``quoka.tp_plan_candidates``) and only plan indices cross the
    interconnect; materialize then runs on the replicated cache exactly as
    in the meshless path.
    """
    t = k.shape[1]
    budget = floor_to_grid(min(budget or sel_scores.resolve_budget(cfg, t),
                               t), grid(cfg))
    if method == "quoka":
        info = qk._tp_route(k, cfg)
        if info is not None:
            q = qk.sanitize_queries(q, q_valid)
            qs = qk.subselect_queries(q, cfg.n_queries, n_kv=k.shape[2],
                                      q_valid=q_valid)
            valid = prior_context_valid(key_pos, chunk_start)
            return SelectionPlan(idx=qk.tp_plan_candidates(
                qs, k, key_pos, valid, cfg, budget, info))
    scores = plan_scores(method, q, k, key_pos, chunk_start, cfg,
                         q_valid=q_valid)
    return plan_from_scores(scores, key_pos, cfg, budget=budget)


def select(method: str, q, k, v, key_pos, chunk_start, cfg: QuokaConfig,
           budget: Optional[int] = None,
           q_valid: Optional[jax.Array] = None) -> Selected:
    """All three stages: the drop-in selection call for one-shot callers
    (``full`` must be handled by the caller — it means 'do not select')."""
    pln = build(method, q, k, key_pos, chunk_start, cfg, budget=budget,
                q_valid=q_valid)
    return materialize(pln, k, v, key_pos, chunk_start, cfg)


# ----------------------------------------------------------------------------
# cross-layer reuse
# ----------------------------------------------------------------------------

def empty_carry(shape) -> PlanCarry:
    """An invalid carry of the given ``plan_idx_shape`` — forces the first
    plan-capable layer to build."""
    return PlanCarry(idx=jnp.full(shape, -1, jnp.int32),
                     valid=jnp.zeros((), bool))


def _refresh_decision(carry: PlanCarry, layer_idx, cfg: QuokaConfig):
    """Traced () bool: does layer L rebuild?  (invalid carry, the interval
    grid, or a correction layer.)  Shared by the plain and obs refresh
    paths so the reuse schedule cannot drift between them."""
    s = max(1, cfg.reuse_interval)
    li = jnp.asarray(layer_idx, jnp.int32)
    do = (~carry.valid) | (li % s == 0)
    if cfg.correction_layers:
        corr = jnp.asarray(cfg.correction_layers, jnp.int32)
        do = do | jnp.any(li == corr)
    return do


def refresh(carry: Optional[PlanCarry], layer_idx, cfg: QuokaConfig,
            build_fn) -> tuple:
    """Per-layer reuse decision: (plan for this layer, updated carry).

    With no carry (reuse disabled / unsupported geometry) every layer
    builds.  Otherwise layer L rebuilds iff the carry is still invalid,
    L % reuse_interval == 0, or L is a correction layer; in between, the
    carried indices are reused as-is.  ``layer_idx`` is the traced GLOBAL
    layer index (models/stack.py computes it across stacks), so reuse runs
    span stack boundaries whenever the plan geometry matches.
    """
    if carry is None:
        return build_fn(), None
    do = _refresh_decision(carry, layer_idx, cfg)
    idx = jax.lax.cond(do, lambda: build_fn().idx, lambda: carry.idx)
    return SelectionPlan(idx=idx), PlanCarry(idx=idx,
                                             valid=jnp.ones((), bool))


# ----------------------------------------------------------------------------
# in-jit telemetry (the aux-stats pytree — see LayerObs)
# ----------------------------------------------------------------------------

def nan_obs() -> LayerObs:
    """The all-NaN LayerObs for layers that never select (recurrent /
    encoder blocks) — keeps the per-layer stats pytree uniform so the stack
    scan can stack it as ys."""
    n = jnp.full((), jnp.nan, jnp.float32)
    return LayerObs(n, n, n, n, n, n, n)


def score_sketch(scores: jax.Array) -> jax.Array:
    """(3,) f32 [min, mean, max] of stage-1 scores over VALID slots.

    Must be taken on the raw ``plan_scores`` output: ``plan_from_scores``
    stamps +inf onto sink slots, which would corrupt the max.  All-invalid
    views (first chunk: no prior context) sketch as NaN.
    """
    ok = scores > NEG_INF / 2
    n = jnp.sum(ok)
    lo = jnp.min(jnp.where(ok, scores, jnp.inf))
    hi = jnp.max(jnp.where(ok, scores, -jnp.inf))
    mean = jnp.sum(jnp.where(ok, scores, 0.0)) / jnp.maximum(n, 1)
    sk = jnp.stack([lo, mean, hi]).astype(jnp.float32)
    return jnp.where(n > 0, sk, jnp.full((3,), jnp.nan, jnp.float32))


def _nan_sketch() -> jax.Array:
    return jnp.full((3,), jnp.nan, jnp.float32)


def dense_obs(key_pos, chunk_start) -> LayerObs:
    """LayerObs for a dense (no-selection) attention layer: every selectable
    prior token is attended, there is no budget and no score pass."""
    valid = prior_context_valid(key_pos, chunk_start)
    ctxc = jnp.mean(jnp.sum(valid, axis=-1).astype(jnp.float32))
    n = jnp.full((), jnp.nan, jnp.float32)
    return LayerObs(sel_tokens=ctxc, ctx_tokens=ctxc, budget_tokens=n,
                    refreshed=jnp.zeros((), jnp.float32),
                    score_lo=n, score_mean=n, score_hi=n)


def selected_obs(sel_pos, key_pos, chunk_start, budget: int, refreshed,
                 sketch) -> LayerObs:
    """LayerObs for a selecting layer, from the materialized budget's
    validity (``sel_pos == -1`` marks padding — exactly what downstream
    attention masks, so sel_tokens counts KV pairs actually attended)."""
    selc = jnp.mean(jnp.sum(sel_pos >= 0, axis=-1).astype(jnp.float32))
    valid = prior_context_valid(key_pos, chunk_start)
    ctxc = jnp.mean(jnp.sum(valid, axis=-1).astype(jnp.float32))
    return LayerObs(sel_tokens=selc, ctx_tokens=ctxc,
                    budget_tokens=jnp.full((), float(budget), jnp.float32),
                    refreshed=jnp.asarray(refreshed, jnp.float32),
                    score_lo=sketch[0], score_mean=sketch[1],
                    score_hi=sketch[2])


def build_obs(method: str, q, k, key_pos, chunk_start, cfg: QuokaConfig,
              budget: Optional[int] = None,
              q_valid: Optional[jax.Array] = None):
    """``build`` that also returns the (3,) score sketch.  The TP T-local
    route never materializes global scores, so it sketches NaN — plan
    indices stay bit-exact with ``build`` in every branch."""
    t = k.shape[1]
    budget = floor_to_grid(min(budget or sel_scores.resolve_budget(cfg, t),
                               t), grid(cfg))
    if method == "quoka" and qk._tp_route(k, cfg) is not None:
        return build(method, q, k, key_pos, chunk_start, cfg, budget=budget,
                     q_valid=q_valid), _nan_sketch()
    scores = plan_scores(method, q, k, key_pos, chunk_start, cfg,
                         q_valid=q_valid)
    return (plan_from_scores(scores, key_pos, cfg, budget=budget),
            score_sketch(scores))


def refresh_obs(carry: Optional[PlanCarry], layer_idx, cfg: QuokaConfig,
                build_fn) -> tuple:
    """``refresh`` for an obs-carrying ``build_fn`` (returns (plan, sketch)).

    Returns ((plan, sketch), updated carry, refreshed () f32).  The sketch
    is a ``lax.cond`` output: the reuse branch yields NaN (scores are never
    computed there — that is the whole point of reuse)."""
    if carry is None:
        pln, sk = build_fn()
        return (pln, sk), None, jnp.ones((), jnp.float32)
    do = _refresh_decision(carry, layer_idx, cfg)

    def _built():
        pln, sk = build_fn()
        return pln.idx, sk

    idx, sk = jax.lax.cond(do, _built, lambda: (carry.idx, _nan_sketch()))
    return ((SelectionPlan(idx=idx), sk),
            PlanCarry(idx=idx, valid=jnp.ones((), bool)),
            do.astype(jnp.float32))


def select_with_ctx(ctx, plan, method: str, q, k, v, key_pos, chunk_start,
                    cfg: QuokaConfig, budget: Optional[int] = None,
                    q_valid: Optional[jax.Array] = None):
    """The block-facing selection entry: refresh-or-build + materialize.

    Returns (Selected, updated plan carry).  When ``ctx["obs"]`` is set,
    the layer's ``LayerObs`` is left in ``ctx["_obs"]`` for the stack scan
    body to pop (the MoE aux-loss side-channel pattern — ``ctx`` is already
    a per-layer copy whenever obs is on, see models/stack.py).  When obs is
    off this is byte-identical to the refresh + materialize it replaced.
    """
    li = ctx.get("layer_idx", 0)
    if not ctx.get("obs"):
        pln, plan = refresh(
            plan, li, cfg,
            lambda: build(method, q, k, key_pos, chunk_start, cfg,
                          budget=budget, q_valid=q_valid))
        _note_block_counts(ctx, pln, cfg)
        return materialize(pln, k, v, key_pos, chunk_start, cfg), plan
    t = k.shape[1]
    bud = floor_to_grid(min(budget or sel_scores.resolve_budget(cfg, t), t),
                        grid(cfg))
    (pln, sketch), plan, refreshed = refresh_obs(
        plan, li, cfg,
        lambda: build_obs(method, q, k, key_pos, chunk_start, cfg,
                          budget=bud, q_valid=q_valid))
    sel = materialize(pln, k, v, key_pos, chunk_start, cfg)
    ctx["_obs"] = selected_obs(sel.pos, key_pos, chunk_start, bud,
                               refreshed, sketch)
    _note_block_counts(ctx, pln, cfg)
    return sel, plan


def _note_block_counts(ctx, pln: SelectionPlan, cfg: QuokaConfig) -> None:
    """Leave this layer's ``pool_block_counts`` in ``ctx["_selblk"]`` when
    the caller asked for the prefetch-oracle side channel
    (``ctx["selblk"] = (block_size, n_blocks)``) — same pop-from-ctx
    pattern as ``ctx["_obs"]``; models/stack.py collects it as scan ys."""
    sb = ctx.get("selblk") if isinstance(ctx, dict) else None
    if sb is not None:
        ctx["_selblk"] = pool_block_counts(pln, cfg, sb[0], sb[1])


# ----------------------------------------------------------------------------
# gather-free fused path (kernels/selected_attention.py)
# ----------------------------------------------------------------------------

def pool_block_counts(plan: SelectionPlan, cfg: QuokaConfig,
                      block_size: int, n_blocks: int) -> jax.Array:
    """(b, n_blocks) int32: how many of this plan's selected entries land
    in each LOGICAL pool block of the request's cache view — the plan's
    indices read off BEFORE materialize, which is what makes QUOKA's
    stage-2 output double as the host-tier prefetch oracle (the engine
    aggregates these into a per-logical-offset hotness ranking that orders
    which demoted blocks to stage first; see serving/engine.py).

    Token plans (g == 1) map slots to blocks by division; block plans map
    grid ids through the grid/block ratio.  Padding (-1) drops."""
    g = grid(cfg)
    idx = plan.idx
    if g == 1:
        flat = idx.reshape(idx.shape[0], -1)       # (b, n_kv * B) slots
        ids = flat // block_size
    else:
        flat = idx                                  # (b, NB) grid ids
        ids = (flat * g) // block_size
    ids = jnp.where(flat >= 0, ids, n_blocks)      # padding -> out of range
    rows = jnp.arange(ids.shape[0], dtype=jnp.int32)[:, None]
    return jnp.zeros((ids.shape[0], n_blocks), jnp.int32).at[rows, ids].add(
        1, mode="drop")


def fused_route(cfg: QuokaConfig, method: str, k,
                window: Optional[int] = None) -> bool:
    """Static dispatch rule: may the gather-free fused selected-attention
    kernel replace the staged materialize + attend pair for this call site?

    The fused kernel streams whole (g, n_kv, d) slabs through its index
    maps, so it serves exactly the geometries where that is well-defined:

      * ``cfg.fused_select_attn`` opted in (default off — the staged path
        stays the baseline and every bit-exactness suite keeps its oracle);
      * block-granular plans only (granularity > 1, head-shared ids) whose
        grid divides the cache view;
      * no sliding window (the per-query window constraint cannot be
        expressed by the kernel's static boundary + per-key masks) — MLA's
        latent-space selection never reaches this router at all;
      * no active mesh policy: pallas_call under GSPMD partitioning (and
        the TP T-local scoring route) stays on the staged path.
    """
    if not getattr(cfg, "fused_select_attn", False):
        return False
    if window is not None:
        return False
    g = grid(cfg)
    if g <= 1:
        return False                      # token-slot plans stay staged
    if k.shape[1] % g:
        return False
    if shctx.get_policy()[0] is not None:
        return False
    return True


def plan_selected_pos(plan: SelectionPlan, key_pos, chunk_start,
                      cfg: QuokaConfig) -> jax.Array:
    """Positions-only twin of ``materialize`` for telemetry: the selected
    positions (-1 = padding) with validity re-derived exactly as
    materialize derives it, WITHOUT touching K/V.  The fused kernel applies
    the same masks in-kernel; this keeps ``LayerObs.sel_tokens`` exact
    while gathering only the (b, T) int32 positions — bytes, not the KV
    budget the fused path exists to avoid."""
    b, t = key_pos.shape
    g = grid(cfg)
    valid = prior_context_valid(key_pos, chunk_start)
    if g == 1:
        top_i = plan.idx                                     # (b, n_kv, B)
        safe = jnp.maximum(top_i, 0)
        shape = top_i.shape[:2] + (t,)
        pos = jnp.take_along_axis(
            jnp.broadcast_to(key_pos[:, None, :], shape), safe, axis=2)
        ok = jnp.take_along_axis(
            jnp.broadcast_to(valid[:, None, :], shape), safe, axis=2)
        return jnp.where((top_i >= 0) & ok, pos, -1)
    blocks = jnp.maximum(plan.idx, 0)                        # (b, NB)
    pos_sel = jnp.take_along_axis(key_pos.reshape(b, t // g, g),
                                  blocks[:, :, None], axis=1)
    ok_sel = jnp.take_along_axis(valid.reshape(b, t // g, g),
                                 blocks[:, :, None], axis=1)
    good = ok_sel & (plan.idx >= 0)[:, :, None]
    return jnp.where(good, pos_sel, -1).reshape(b, 1, -1)


def fused_attend_with_ctx(ctx, plan, method: str, q, k, v, key_pos,
                          chunk_start, cfg: QuokaConfig,
                          budget: Optional[int] = None,
                          q_valid: Optional[jax.Array] = None):
    """Fused twin of ``select_with_ctx`` + the block's staged attention:
    refresh-or-build the plan, then attend straight THROUGH its indices via
    ``kops.selected_attention`` — no materialize, no [budget | chunk]
    concat, one kernel launch.  Callers gate on ``fused_route``.

    Returns (att (b, t, h, d), updated plan carry); the obs side-channel
    contract matches select_with_ctx (``ctx["_obs"]`` from the positions-
    only gather, so telemetry stays exact without the KV round-trip).
    """
    li = ctx.get("layer_idx", 0)
    be = ctx.get("backend")
    g = grid(cfg)
    t = k.shape[1]
    bud = floor_to_grid(min(budget or sel_scores.resolve_budget(cfg, t), t),
                        g)
    if not ctx.get("obs"):
        pln, plan = refresh(
            plan, li, cfg,
            lambda: build(method, q, k, key_pos, chunk_start, cfg,
                          budget=bud, q_valid=q_valid))
        att = kops.selected_attention(q, k, v, key_pos, pln.idx,
                                      chunk_start, granularity=g,
                                      backend=be, cfg=cfg)
        _note_block_counts(ctx, pln, cfg)
        return att, plan
    (pln, sketch), plan, refreshed = refresh_obs(
        plan, li, cfg,
        lambda: build_obs(method, q, k, key_pos, chunk_start, cfg,
                          budget=bud, q_valid=q_valid))
    att = kops.selected_attention(q, k, v, key_pos, pln.idx, chunk_start,
                                  granularity=g, backend=be, cfg=cfg)
    ctx["_obs"] = selected_obs(
        plan_selected_pos(pln, key_pos, chunk_start, cfg), key_pos,
        chunk_start, bud, refreshed, sketch)
    _note_block_counts(ctx, pln, cfg)
    return att, plan
