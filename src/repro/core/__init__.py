"""The paper's contribution: QUOKA selection (quoka.py), competing selection
baselines (selection.py), and the chunked-prefill harness."""
