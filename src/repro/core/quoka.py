"""QUOKA — Query-oriented KV selection (paper Algorithm 1).

Three stages, all standard linear algebra (the paper's portability claim):

  1. *Query subselection* — keep the ``N_Q`` queries most cosine-DISSIMILAR
     to the mean query of the chunk (Theorem 1: those dominate attention).
  2. *Cosine-similarity scoring* — score the kept (normalised) queries
     against normalised cached keys.
  3. *Group-aware aggregation* — **max** over the query axis (preserves
     heavy-tailed outliers, Table 10) and **mean** over GQA groups, applied
     as *pre-aggregation*: normalised queries are averaged inside each KV
     group BEFORE the ``Q̄Kᵀ`` matmul (linearity), cutting score cost by
     ``n_q/n_kv`` (paper §3.3, Table 4).

Layouts: q (b, t, n_q_heads, d); k/v caches (b, T, n_kv, d);
key positions (b, T) int32 with -1 marking empty slots.
Scores are fp32; ``NEG_INF`` marks un-selectable slots.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import QuokaConfig
from repro.core.attention import NEG_INF
from repro.models.layers import l2_normalize


class Selected(NamedTuple):
    """A gathered KV budget.  Positions are per-KV-head (b, n_kv, B);
    -1 marks padding (fewer valid KVs than the budget)."""
    k: jax.Array          # (b, B, n_kv, d)
    v: jax.Array          # (b, B, n_kv, d)
    pos: jax.Array        # (b, n_kv, B) int32
    idx: jax.Array        # (b, n_kv, B) int32 cache slots (for analysis)


# ----------------------------------------------------------------------------
# stage 1: query subselection
# ----------------------------------------------------------------------------

def subselect_queries(q: jax.Array, n_queries: int,
                      n_kv: Optional[int] = None) -> jax.Array:
    """Keep the ``n_queries`` queries with lowest CosSim to the mean query.

    q: (b, t, h, d)  ->  (b, n_queries, h, d).
    When t <= n_queries the input is returned unchanged (Algorithm 1 line 1).

    With ``n_kv`` given, selection is GROUP-COHERENT: the dissimilarity score
    is averaged over each GQA group and every head of a group keeps the SAME
    token indices.  This is required for the downstream pre-aggregation
    (quoka_scores averages normalised queries inside each group): with
    independent per-head top-k, slot i holds a *different token* per head and
    the group mean blends unrelated queries, washing outliers out before the
    max.  Outlier-ness is token-level in GQA models (heads of a group retrieve
    the same token — the premise of §3.3's pre-aggregation), so the group-mean
    score preserves exactly the queries pre-aggregation can represent.
    Without ``n_kv`` (or with n_kv == h) selection is per-head as before.
    """
    b, t, h, d = q.shape
    if t <= n_queries:
        return q
    qf = q.astype(jnp.float32)
    mq = jnp.mean(qf, axis=1, keepdims=True)                     # (b, 1, h, d)
    num = jnp.sum(qf * mq, axis=-1)
    den = (jnp.linalg.norm(qf, axis=-1) * jnp.linalg.norm(mq, axis=-1) + 1e-8)
    s_q = -(num / den)                                           # (b, t, h)
    if n_kv is not None and n_kv != h:
        group = h // n_kv
        s_g = s_q.reshape(b, t, n_kv, group).mean(axis=3)        # (b, t, n_kv)
        _, top_g = jax.lax.top_k(s_g.transpose(0, 2, 1), n_queries)
        top_i = jnp.repeat(top_g, group, axis=1)                 # (b, h, N_Q)
    else:
        _, top_i = jax.lax.top_k(s_q.transpose(0, 2, 1), n_queries)
    gathered = jnp.take_along_axis(
        q.transpose(0, 2, 1, 3), top_i[..., None], axis=2)       # (b, h, N_Q, d)
    return gathered.transpose(0, 2, 1, 3)


# ----------------------------------------------------------------------------
# stages 2+3: cosine scoring with GQA pre-aggregation, max over queries
# ----------------------------------------------------------------------------

def quoka_scores(q: jax.Array, k: jax.Array, valid: jax.Array,
                 cfg: QuokaConfig) -> jax.Array:
    """Paper Algorithm 1 lines 6-10.

    q: (b, N_Q, n_q_heads, d) already sub-selected; k: (b, T, n_kv, d);
    valid: (b, T) bool (selectable prior-context slots).
    Returns fp32 scores (b, n_kv, T), NEG_INF on invalid slots.

    Backend dispatch: the default cosine+max configuration routes through
    ``kernels/ops.py::score`` (the fused Pallas scoring kernel, or its XLA
    twin below) per the resolved ``cfg.backend``.  The Table-9/10 ablation
    arms ("dot" scoring, "mean" aggregation) are outside the kernel's fixed
    semantics and always take the einsum path.
    """
    b, nq, h, d = q.shape
    n_kv = k.shape[2]
    group = h // n_kv

    if cfg.scoring == "cosine":
        qn = l2_normalize(q.astype(jnp.float32))
    elif cfg.scoring == "dot":                     # Table 9 ablation arm
        qn = q.astype(jnp.float32)
    else:
        raise ValueError(cfg.scoring)

    # pre-aggregation: mean of (normalised) queries inside each KV group
    qbar = jnp.mean(qn.reshape(b, nq, n_kv, group, d), axis=3)   # (b,N_Q,n_kv,d)

    if cfg.scoring == "cosine" and cfg.query_agg == "max":
        from repro.kernels import ops as kops
        backend = kops.resolve_backend(cfg=cfg)
        if backend != "xla":
            # fused kernel path: Q̄ stays VMEM-resident, K streamed once
            return kops.score(qbar, k, valid, backend=backend)
    # FUSED key normalisation (§Perf A1): scores are divided by per-key norms
    # instead of materialising a normalised (fp32!) copy of the whole K cache
    # — K is streamed once, in its storage dtype, by a single einsum.  This
    # is the XLA twin of the kernels/quoka_score.py in-VMEM normalisation.
    # NOTE (§Perf A7): scoring is embarrassingly parallel over the KEY axis,
    # and when n_kv < |model| (granite kv=8 on 16-way TP) it under-shards.
    # Constraining the score tensor's T axis over `model` was measured at
    # 60 TB/chip of all-gather — XLA reshards the whole K cache to satisfy
    # the second layout.  A T-local scoring pass needs the CACHE stored
    # score-major (or a shard_map with a layout-local kernel); left as
    # documented future work.
    s = jnp.einsum("bnkd,btkd->bknt", qbar.astype(k.dtype), k,
                   preferred_element_type=jnp.float32)           # (b,n_kv,N_Q,T)
    if cfg.scoring == "cosine":
        # self-dot via einsum: bf16 reads, fp32 accumulation — no converted
        # copy of K is ever materialised (an astype(f32) here caused XLA to
        # hoist a full-cache f32 conversion across the prefill loop)
        sq = jnp.einsum("btkd,btkd->btk", k, k,
                        preferred_element_type=jnp.float32)
        inv = jax.lax.rsqrt(sq + 1e-16)                          # (b,T,n_kv)
        s = s * inv.transpose(0, 2, 1)[:, :, None, :]

    if cfg.query_agg == "max":                     # Table 10: max >> mean
        s_hat = jnp.max(s, axis=2)
    elif cfg.query_agg == "mean":
        s_hat = jnp.mean(s, axis=2)
    else:
        raise ValueError(cfg.query_agg)

    return jnp.where(valid[:, None, :], s_hat, NEG_INF)


# ----------------------------------------------------------------------------
# topk + gather (Algorithm 1 lines 11-12) — shared by every scoring method
# ----------------------------------------------------------------------------

def select_topk(scores: jax.Array, k: jax.Array, v: jax.Array,
                key_pos: jax.Array, budget: int, *,
                keep_first: int = 0) -> Selected:
    """Gather the ``budget`` best KVs per (batch, kv-head).

    scores: (b, n_kv, T) fp32 with NEG_INF on invalid slots.
    k, v: (b, T, n_kv, d); key_pos: (b, T).
    """
    b, n_kv, t = scores.shape
    budget = min(budget, t)
    if keep_first:
        # sink protection: force-keep the first `keep_first` real tokens
        sink = (key_pos >= 0) & (key_pos < keep_first)           # (b, T)
        scores = jnp.where(sink[:, None, :] & (scores > NEG_INF / 2),
                           jnp.inf, scores)
    top_s, top_i = jax.lax.top_k(scores, budget)                 # (b, n_kv, B)
    good = top_s > NEG_INF / 2

    # gather along the TIME axis directly — transposing the K/V caches first
    # would materialise a full-cache copy per chunk per layer (§Perf A5)
    idx_t = top_i.transpose(0, 2, 1)[..., None]                  # (b,B,n_kv,1)
    k_sel = jnp.take_along_axis(k, idx_t, axis=1)                # (b,B,n_kv,d)
    v_sel = jnp.take_along_axis(v, idx_t, axis=1)
    pos = jnp.take_along_axis(
        jnp.broadcast_to(key_pos[:, None, :], scores.shape), top_i, axis=2)
    pos = jnp.where(good, pos, -1)
    return Selected(k=k_sel, v=v_sel,
                    pos=pos, idx=jnp.where(good, top_i, -1))


def prior_context_valid(key_pos: jax.Array, chunk_start) -> jax.Array:
    """Selectable slots: 0 <= pos < chunk_start (the prior context, eq. (2)).

    ``chunk_start`` may be a traced scalar (scan carry) or a per-row ``(b,)``
    vector (continuous batching: requests in one step batch sit at different
    positions)."""
    cs = jnp.asarray(chunk_start, jnp.int32)
    if cs.ndim == 1:
        cs = cs[:, None]
    return (key_pos >= 0) & (key_pos < cs)


def quoka_select(q: jax.Array, k: jax.Array, v: jax.Array,
                 key_pos: jax.Array, chunk_start, cfg: QuokaConfig,
                 budget: Optional[int] = None) -> Selected:
    """Full Algorithm 1: subselect queries, score, topk-gather.

    ``chunk_start`` may be traced (scan carry) and scalar or per-row;
    selection considers only prior-context slots (eq. (2)).
    """
    qs = subselect_queries(q, cfg.n_queries, n_kv=k.shape[2])
    valid = prior_context_valid(key_pos, chunk_start)
    scores = quoka_scores(qs, k, valid, cfg)
    return select_topk(scores, k, v, key_pos, budget or cfg.budget,
                       keep_first=cfg.keep_first)
