"""QUOKA scoring primitives (paper Algorithm 1, stages 1-3).

Three stages, all standard linear algebra (the paper's portability claim):

  1. *Query subselection* — keep the ``N_Q`` queries most cosine-DISSIMILAR
     to the mean query of the chunk (Theorem 1: those dominate attention).
  2. *Cosine-similarity scoring* — score the kept (normalised) queries
     against normalised cached keys.
  3. *Group-aware aggregation* — **max** over the query axis (preserves
     heavy-tailed outliers, Table 10) and **mean** over GQA groups, applied
     as *pre-aggregation*: normalised queries are averaged inside each KV
     group BEFORE the ``Q̄Kᵀ`` matmul (linearity), cutting score cost by
     ``n_q/n_kv`` (paper §3.3, Table 4).

This module produces SCORES (and, on the tensor-parallel fast path,
top-k plan candidates).  The select + materialize stages live in
``core/plan.py::SelectionPlan`` — the single selection code path for every
caller (attention blocks, the standalone chunked-prefill harness, the
serving engine).

Layouts: q (b, t, n_q_heads, d); k/v caches (b, T, n_kv, d);
key positions (b, T) int32 with -1 marking empty slots.
Scores are fp32; ``NEG_INF`` marks un-selectable slots.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import QuokaConfig
from repro.core.attention import NEG_INF
from repro.models.layers import l2_normalize
from repro.sharding import ctx as shctx


class Selected(NamedTuple):
    """A gathered KV budget.  Positions are per-KV-head (b, n_kv, B);
    -1 marks padding (fewer valid KVs than the budget)."""
    k: jax.Array          # (b, B, n_kv, d)
    v: jax.Array          # (b, B, n_kv, d)
    pos: jax.Array        # (b, n_kv, B) int32
    idx: jax.Array        # (b, n_kv, B) int32 cache slots (for analysis)


# ----------------------------------------------------------------------------
# stage 1: query subselection
# ----------------------------------------------------------------------------

def sanitize_queries(q: jax.Array, q_valid: Optional[jax.Array]) -> jax.Array:
    """Replace invalid query rows with a copy of the row's batch-first VALID
    query.

    ``q_valid`` (b, t) marks real queries; False rows are padding (ragged
    tail chunks under continuous batching, left-pad slots of ``pad_prompt``)
    whose projections come from garbage embeddings.  Overwriting them with a
    duplicate of a real query makes every later stage safe by construction:
    a duplicate can never change a max-aggregated score, and downstream
    masking (``subselect_queries``) keeps duplicates out of the mean/top-k
    whenever enough real queries exist."""
    if q_valid is None:
        return q
    first = jnp.argmax(q_valid, axis=1)                          # (b,)
    repl = jnp.take_along_axis(q, first[:, None, None, None], axis=1)
    return jnp.where(q_valid[:, :, None, None], q, repl)


def subselect_queries(q: jax.Array, n_queries: int,
                      n_kv: Optional[int] = None,
                      q_valid: Optional[jax.Array] = None) -> jax.Array:
    """Keep the ``n_queries`` queries with lowest CosSim to the mean query.

    q: (b, t, h, d)  ->  (b, n_queries, h, d).
    When t <= n_queries the input is returned unchanged (Algorithm 1 line 1).

    With ``n_kv`` given, selection is GROUP-COHERENT: the dissimilarity score
    is averaged over each GQA group and every head of a group keeps the SAME
    token indices.  This is required for the downstream pre-aggregation
    (quoka_scores averages normalised queries inside each group): with
    independent per-head top-k, slot i holds a *different token* per head and
    the group mean blends unrelated queries, washing outliers out before the
    max.  Outlier-ness is token-level in GQA models (heads of a group retrieve
    the same token — the premise of §3.3's pre-aggregation), so the group-mean
    score preserves exactly the queries pre-aggregation can represent.
    Without ``n_kv`` (or with n_kv == h) selection is per-head as before.

    ``q_valid`` (b, t) bool masks ragged-tail padding: invalid rows are
    excluded from the mean query AND ranked last by top-k, so garbage
    embeddings cannot skew the chunk statistics (callers should first run
    ``sanitize_queries`` so any invalid row that IS kept — fewer valid
    queries than ``n_queries`` — is a harmless duplicate of a real one).
    """
    b, t, h, d = q.shape
    if t <= n_queries:
        return q
    qf = q.astype(jnp.float32)
    if q_valid is not None:
        w = q_valid[:, :, None, None].astype(jnp.float32)
        cnt = jnp.maximum(jnp.sum(w, axis=1, keepdims=True), 1.0)
        mq = jnp.sum(qf * w, axis=1, keepdims=True) / cnt        # (b, 1, h, d)
    else:
        mq = jnp.mean(qf, axis=1, keepdims=True)                 # (b, 1, h, d)
    num = jnp.sum(qf * mq, axis=-1)
    den = (jnp.linalg.norm(qf, axis=-1) * jnp.linalg.norm(mq, axis=-1) + 1e-8)
    s_q = -(num / den)                                           # (b, t, h)
    if q_valid is not None:
        s_q = jnp.where(q_valid[:, :, None], s_q, -jnp.inf)
    if n_kv is not None and n_kv != h:
        group = h // n_kv
        s_g = s_q.reshape(b, t, n_kv, group).mean(axis=3)        # (b, t, n_kv)
        _, top_g = jax.lax.top_k(s_g.transpose(0, 2, 1), n_queries)
        top_i = jnp.repeat(top_g, group, axis=1)                 # (b, h, N_Q)
    else:
        _, top_i = jax.lax.top_k(s_q.transpose(0, 2, 1), n_queries)
    gathered = jnp.take_along_axis(
        q.transpose(0, 2, 1, 3), top_i[..., None], axis=2)       # (b, h, N_Q, d)
    return gathered.transpose(0, 2, 1, 3)


# ----------------------------------------------------------------------------
# stages 2+3: cosine scoring with GQA pre-aggregation, max over queries
# ----------------------------------------------------------------------------

def quoka_scores(q: jax.Array, k: jax.Array, valid: jax.Array,
                 cfg: QuokaConfig) -> jax.Array:
    """Paper Algorithm 1 lines 6-10.

    q: (b, N_Q, n_q_heads, d) already sub-selected; k: (b, T, n_kv, d);
    valid: (b, T) bool (selectable prior-context slots).
    Returns fp32 scores (b, n_kv, T), NEG_INF on invalid slots.

    Backend dispatch: the default cosine+max configuration routes through
    ``kernels/ops.py::score`` (the fused Pallas scoring kernel, or its XLA
    twin below) per the resolved ``cfg.backend``.  The Table-9/10 ablation
    arms ("dot" scoring, "mean" aggregation) are outside the kernel's fixed
    semantics and always take the einsum path.
    """
    b, nq, h, d = q.shape
    n_kv = k.shape[2]
    group = h // n_kv

    if cfg.scoring == "cosine":
        qn = l2_normalize(q.astype(jnp.float32))
    elif cfg.scoring == "dot":                     # Table 9 ablation arm
        qn = q.astype(jnp.float32)
    else:
        raise ValueError(cfg.scoring)

    # pre-aggregation: mean of (normalised) queries inside each KV group
    qbar = jnp.mean(qn.reshape(b, nq, n_kv, group, d), axis=3)   # (b,N_Q,n_kv,d)

    if cfg.scoring == "cosine" and cfg.query_agg == "max":
        from repro.kernels import ops as kops
        backend = kops.resolve_backend(cfg=cfg)
        # facade path: the fused Pallas kernel (Q̄ VMEM-resident, K streamed
        # once) or its XLA twin with FUSED key normalisation (§Perf A1 —
        # scores divided by per-key norms so no normalised fp32 copy of the
        # K cache is ever materialised).  Tensor-parallel serving runs the
        # SAME facade per shard inside tp_plan_candidates' shard_map below —
        # that T-local pass is what resolved the old §Perf A7 note: when
        # n_kv < |model| the (b, n_kv, T) score tensor under-shards, and
        # constraining its T axis over `model` made XLA reshard the whole K
        # cache (measured 60 TB/chip of all-gather).  shard_map scores each
        # key where it lives and merges per-shard top-k candidates instead.
        return kops.score(qbar, k, valid, backend=backend,
                          proj=score_proj(cfg, d))
    # ablation arms ("dot" scoring / "mean" aggregation) are outside the
    # kernel's fixed semantics and keep the einsum path
    s = jnp.einsum("bnkd,btkd->bknt", qbar.astype(k.dtype), k,
                   preferred_element_type=jnp.float32)           # (b,n_kv,N_Q,T)
    if cfg.scoring == "cosine":
        # self-dot via einsum: bf16 reads, fp32 accumulation — no converted
        # copy of K is ever materialised (an astype(f32) here caused XLA to
        # hoist a full-cache f32 conversion across the prefill loop)
        sq = jnp.einsum("btkd,btkd->btk", k, k,
                        preferred_element_type=jnp.float32)
        inv = jax.lax.rsqrt(sq + 1e-16)                          # (b,T,n_kv)
        s = s * inv.transpose(0, 2, 1)[:, :, None, :]

    if cfg.query_agg == "max":                     # Table 10: max >> mean
        s_hat = jnp.max(s, axis=2)
    elif cfg.query_agg == "mean":
        s_hat = jnp.mean(s, axis=2)
    else:
        raise ValueError(cfg.query_agg)

    return jnp.where(valid[:, None, :], s_hat, NEG_INF)


def score_proj(cfg: QuokaConfig, d: int):
    """The cached low-rank scoring projection for ``cfg.score_proj_dim``,
    or None when the mode is off (or would not reduce the head dim)."""
    r = getattr(cfg, "score_proj_dim", 0)
    if not r or r >= d:
        return None
    from repro.kernels import ops as kops
    return kops.score_projection(d, r)


def prior_context_valid(key_pos: jax.Array, chunk_start) -> jax.Array:
    """Selectable slots: 0 <= pos < chunk_start (the prior context, eq. (2)).

    ``chunk_start`` may be a traced scalar (scan carry) or a per-row ``(b,)``
    vector (continuous batching: requests in one step batch sit at different
    positions)."""
    cs = jnp.asarray(chunk_start, jnp.int32)
    if cs.ndim == 1:
        cs = cs[:, None]
    return (key_pos >= 0) & (key_pos < cs)


# ----------------------------------------------------------------------------
# tensor-parallel T-local selection (shard_map over the `model` axis)
# ----------------------------------------------------------------------------

def _tp_route(k: jax.Array, cfg: QuokaConfig):
    """Shard info when the T-local sharded selection path applies.

    The einsum/kernel path already shards well whenever the KV-head axis
    divides the `model` axis (scores shard over heads).  The failure mode —
    the old §Perf A7 note — is n_kv < |model| (granite kv=8 on 16-way TP):
    the score tensor under-shards and any attempt to constrain its T axis
    resharded the whole K cache.  In exactly that regime the cache's head
    axis is REPLICATED over `model` (sharding/specs.py drops indivisible
    axes), so each shard can score a distinct contiguous T-slice of the
    keys it already holds, locally, and only candidate (score, index)
    pairs — ``budget`` per shard — cross the interconnect."""
    if cfg.scoring != "cosine" or cfg.query_agg != "max":
        return None                        # ablation arms: einsum fallback
    info = shctx.tp_shard_info()
    if info is None:
        return None                        # no mesh policy: einsum fallback
    mesh, m_ax, _ = info
    msize = mesh.shape[m_ax]
    t, n_kv = k.shape[1], k.shape[2]
    if n_kv % msize == 0:
        return None                        # heads shard: already layout-local
    if t % msize != 0:
        return None                        # ragged key axis: fall back
    g = max(1, cfg.granularity)
    if (t // msize) % g != 0:
        return None    # selection grid straddles shard slices: fall back
    return info


def tp_plan_candidates(qs: jax.Array, k: jax.Array, key_pos: jax.Array,
                       valid: jax.Array, cfg: QuokaConfig, budget: int,
                       info) -> jax.Array:
    """T-local sharded scoring + candidate merge (old §Perf A7 note).

    Each `model` shard scores a contiguous ``T/|model|`` slice of the keys
    through the same ``kernels/ops.score`` facade as the unsharded path,
    keeps its local top candidates on the selection grid, and the shards
    merge candidates with one SMALL all-gather ((score, idx) pairs per
    shard — a few KB) instead of resharding the K cache.  The merged top-k
    is exactly ``plan.plan_from_scores``'s: descending score with ties
    broken by ascending key/block index (shard slices are contiguous and
    ascending, local top-k orders ties by index, and the merge prefers
    earlier candidate positions), so the returned PLAN INDICES — and
    therefore decoding — are bit-identical to the meshless run.

    Only indices leave the shard_map: the materialize stage runs outside,
    on the replicated caches (core/plan.py), so the same contiguous-gather
    lowering serves the sharded and meshless paths.  Returns the
    ``SelectionPlan.idx`` payload: (b, n_kv, budget) token slots at
    granularity 1, (b, budget//g) block ids at granularity g > 1; -1 marks
    padding.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.kernels import ops as kops
    from repro.sharding.specs import _axes_size

    mesh, m_ax, b_axes = info
    msize = mesh.shape[m_ax]
    b, nq, h, d = qs.shape
    t, n_kv = k.shape[1], k.shape[2]
    g = max(1, cfg.granularity)
    budget = min(budget, t)
    nb = budget // g                                      # plan slots
    tl = t // msize
    n_cand = min(nb, tl // g)                             # per-shard slots
    backend = kops.resolve_backend(cfg=cfg)
    keep_first = cfg.keep_first
    proj = score_proj(cfg, d)

    # pre-aggregation outside the shard_map (cheap, T-independent); the
    # math matches quoka_scores' cosine branch exactly
    qn = l2_normalize(qs.astype(jnp.float32))
    qbar = jnp.mean(qn.reshape(b, nq, n_kv, h // n_kv, d), axis=3)

    b_ax = b_axes if (b_axes and b % _axes_size(mesh, b_axes) == 0) else None

    def body(qbar_l, k_l, pos_l, valid_l):
        i = jax.lax.axis_index(m_ax)
        bb = k_l.shape[0]
        ks = jax.lax.dynamic_slice_in_dim(k_l, i * tl, tl, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(valid_l, i * tl, tl, axis=1)
        ps = jax.lax.dynamic_slice_in_dim(pos_l, i * tl, tl, axis=1)
        s = kops.score(qbar_l, ks, vs, backend=backend,
                       proj=proj)                         # (b, n_kv, tl)
        if keep_first:
            sink = (ps >= 0) & (ps < keep_first)          # plan's sink rule
            s = jnp.where(sink[:, None, :] & (s > NEG_INF / 2), jnp.inf, s)
        if g == 1:
            cs, ci = jax.lax.top_k(s, n_cand)             # local candidates
            ci = ci + i * tl                              # -> global indices
            cs = jax.lax.all_gather(cs, m_ax, axis=2, tiled=True)
            ci = jax.lax.all_gather(ci, m_ax, axis=2, tiled=True)
            top_s, cpos = jax.lax.top_k(cs, budget)       # merge (replicated)
            top_i = jnp.take_along_axis(ci, cpos, axis=2)  # (b, n_kv, B)
            good = top_s > NEG_INF / 2
            return jnp.where(good, top_i, -1)
        # block-granular: pool token scores to the local block grid first —
        # max is associative, so local-max-then-merge equals the meshless
        # reshape-max over the full key axis, element for element
        sb = s.reshape(bb, n_kv, tl // g, g).max(axis=3).max(axis=1)
        cs, ci = jax.lax.top_k(sb, n_cand)                # (b, n_cand)
        ci = ci + i * (tl // g)                           # -> global block ids
        cs = jax.lax.all_gather(cs, m_ax, axis=1, tiled=True)
        ci = jax.lax.all_gather(ci, m_ax, axis=1, tiled=True)
        top_s, cpos = jax.lax.top_k(cs, nb)
        top_i = jnp.take_along_axis(ci, cpos, axis=1)     # (b, NB)
        good = top_s > NEG_INF / 2
        return jnp.where(good, top_i, -1)

    out_spec = P(b_ax, None, None) if g == 1 else P(b_ax, None)
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(b_ax, None, None, None), P(b_ax, None, None, None),
                  P(b_ax, None), P(b_ax, None)),
        out_specs=out_spec,
        check_rep=False)(qbar, k, key_pos, valid)
