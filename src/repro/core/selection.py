"""Sparse-attention selection baselines the paper compares against (§4).

All methods share QUOKA's interface: produce fp32 relevance scores
(b, n_kv, T) over the cached keys; the shared select + materialize stages
live in ``core/plan.py::SelectionPlan``.  This keeps the comparison honest
— only the *scoring policy* differs.

  sample_attention  Zhu et al. 2024      — uniformly sampled queries, true
                                           softmax logits, mean aggregation
  sparq             Ribar et al. 2024    — top-|q| channel subselection,
                                           dot scores, mean aggregation
  loki              Singhania et al.2024 — low-rank projected q/k dot scores
                                           (random projection stands in for
                                           the offline PCA; documented)
  less_is_more      Yang et al. 2025b    — scores only every k-th layer,
                                           indices re-used in between (the
                                           reuse is driven by the engine)
  snapkv            Li et al. 2024       — last-window observation queries,
                                           pooled softmax mass (eviction
                                           policy used as a selector)
  keydiff           Park et al. 2025     — query-free: key dissimilarity
                                           from the mean key
  quoka             this paper
  full              dense attention      — engine bypasses selection
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import QuokaConfig
from repro.core.attention import NEG_INF
from repro.core.quoka import quoka_scores, subselect_queries
from repro.models.layers import l2_normalize

METHODS = ("quoka", "sample_attention", "sparq", "loki", "less_is_more",
           "snapkv", "keydiff", "full")


def _group_mean_q(q, n_kv):
    """(b, t, h, d) -> (b, t, n_kv, d) mean over the GQA group axis."""
    b, t, h, d = q.shape
    return jnp.mean(q.reshape(b, t, n_kv, h // n_kv, d), axis=3)


def _mask(scores, valid):
    return jnp.where(valid[:, None, :], scores, NEG_INF)


# ---------------------------------------------------------------------------
# scoring policies
# ---------------------------------------------------------------------------

def sample_attention_scores(q, k, valid, cfg: QuokaConfig):
    """Uniform query sampling + softmax-logit scores, mean aggregated."""
    b, t, h, d = q.shape
    n_kv = k.shape[2]
    n = min(cfg.n_queries, t)
    idx = jnp.linspace(0, t - 1, n).astype(jnp.int32)            # uniform
    qs = q[:, idx].astype(jnp.float32)                           # (b, n, h, d)
    # per *attention* head logits (the method does NOT pre-aggregate; this is
    # exactly the n_q-vs-n_kv cost difference of paper Table 4)
    kr = jnp.repeat(k.astype(jnp.float32), h // n_kv, axis=2)    # (b, T, h, d)
    logits = jnp.einsum("bnhd,bthd->bhnt", qs, kr) / jnp.sqrt(float(d))
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)                      # (b, h, n, T)
    s = probs.mean(axis=2)                                       # mean over queries
    s = s.reshape(b, n_kv, h // n_kv, -1).mean(axis=2)           # mean over group
    return _mask(s, valid)


def sparq_scores(q, k, valid, cfg: QuokaConfig):
    """Top-r |q| channels, dot-product scores, mean aggregation."""
    n_kv = k.shape[2]
    r = min(cfg.rank, q.shape[-1])
    qg = _group_mean_q(q.astype(jnp.float32), n_kv)              # (b, t, n_kv, d)
    imp = jnp.mean(jnp.abs(qg), axis=1)                          # (b, n_kv, d)
    _, ch = jax.lax.top_k(imp, r)                                # (b, n_kv, r)
    qc = jnp.take_along_axis(qg.transpose(0, 2, 1, 3),
                             ch[:, :, None, :], axis=3)          # (b,n_kv,t,r)
    kc = jnp.take_along_axis(k.astype(jnp.float32).transpose(0, 2, 1, 3),
                             ch[:, :, None, :], axis=3)          # (b,n_kv,T,r)
    s = jnp.einsum("bktr,bksr->bkts", qc, kc).mean(axis=2)       # mean over queries
    return _mask(s, valid)


def loki_scores(q, k, valid, cfg: QuokaConfig):
    """Low-rank projected dot scores ((d, rank) projection: offline PCA in
    the original; a fixed random projection stands in here, JL-style).  The
    projection comes from the process-wide cache shared with the
    ``score_proj_dim`` plan mode (kernels/ops.py::score_projection) — it
    used to be rebuilt on every call, once per chunk per layer."""
    from repro.kernels import ops as kops
    n_kv = k.shape[2]
    d = q.shape[-1]
    r = min(cfg.rank, d)
    proj = kops.score_projection(d, r)
    qg = _group_mean_q(q.astype(jnp.float32), n_kv) @ proj       # (b,t,n_kv,r)
    kl = k.astype(jnp.float32).transpose(0, 2, 1, 3) @ proj      # (b,n_kv,T,r)
    s = jnp.einsum("btkr,bksr->bkts", qg, kl).mean(axis=2)       # mean over q
    return _mask(s, valid)


def less_is_more_scores(q, k, valid, cfg: QuokaConfig):
    """Last-window mean-aggregated dot scores (per-layer reuse is applied by
    the engine, which only *calls* this on scoring layers)."""
    n_kv = k.shape[2]
    w = min(cfg.n_queries, q.shape[1])
    qg = _group_mean_q(q[:, -w:].astype(jnp.float32), n_kv)
    s = jnp.einsum("btkd,bskd->bkts", qg,
                   k.astype(jnp.float32)).mean(axis=2)
    return _mask(s, valid)


def snapkv_scores(q, k, valid, cfg: QuokaConfig, pool: int = 7):
    """Observation-window softmax mass, 1D max-pooled (SnapKV §3)."""
    b, t, h, d = q.shape
    n_kv = k.shape[2]
    w = min(16, t)
    kr = jnp.repeat(k.astype(jnp.float32), h // n_kv, axis=2)
    logits = jnp.einsum("bnhd,bthd->bhnt", q[:, -w:].astype(jnp.float32),
                        kr) / jnp.sqrt(float(d))
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    s = jax.nn.softmax(logits, axis=-1).sum(axis=2)              # (b, h, T)
    s = s.reshape(b, n_kv, h // n_kv, -1).mean(axis=2)
    # 1D max pooling over the key axis (preserve clusters)
    pad = pool // 2
    sp = jnp.pad(s, ((0, 0), (0, 0), (pad, pad)), constant_values=0.0)
    s = jax.lax.reduce_window(sp, -jnp.inf, jax.lax.max,
                              (1, 1, pool), (1, 1, 1), "valid")
    return _mask(s, valid)


def keydiff_scores(q, k, valid, cfg: QuokaConfig):
    """Query-free: keys most dissimilar from the mean key are kept."""
    del q
    kf = k.astype(jnp.float32)
    kn = l2_normalize(kf)
    denom = jnp.maximum(jnp.sum(valid, axis=1, keepdims=True), 1)
    mean_k = jnp.sum(jnp.where(valid[:, :, None, None], kn, 0.0), axis=1,
                     keepdims=True) / denom[:, :, None, None]
    s = -jnp.sum(kn * l2_normalize(mean_k), axis=-1)             # (b, T, n_kv)
    return _mask(s.transpose(0, 2, 1), valid)


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

def compute_scores(method: str, q, k, valid, cfg: QuokaConfig):
    if method == "quoka":
        qs = subselect_queries(q, cfg.n_queries, n_kv=k.shape[2])
        return quoka_scores(qs, k, valid, cfg)
    if method == "sample_attention":
        return sample_attention_scores(q, k, valid, cfg)
    if method == "sparq":
        return sparq_scores(q, k, valid, cfg)
    if method == "loki":
        return loki_scores(q, k, valid, cfg)
    if method == "less_is_more":
        return less_is_more_scores(q, k, valid, cfg)
    if method == "snapkv":
        return snapkv_scores(q, k, valid, cfg)
    if method == "keydiff":
        return keydiff_scores(q, k, valid, cfg)
    raise ValueError(f"unknown selection method {method!r}")


def floor_to_grid(budget: int, g: int) -> int:
    """Floor a token budget onto the g-token selection grid (min one
    block).  Granularity 1 is the identity — legacy budgets unchanged."""
    if g <= 1:
        return budget
    return max(g, budget - budget % g)


def resolve_budget(cfg: QuokaConfig, context_len: int) -> int:
    """Effective B_SA: fixed, or a fraction of the (static) context length
    (paper Table 2 runs B_SA = 25% of the cache) — GRID-ALIGNED.

    A ratio budget can straddle the selection grid (0.25 * 1000 = 250 on a
    16-token grid); flooring happens here, in one place, so no caller ever
    re-rounds (the scheduler/engine/plan all consume this value as-is)."""
    if cfg.budget_ratio is not None:
        budget = max(cfg.keep_first + 1,
                     int(cfg.budget_ratio * context_len))
    else:
        budget = cfg.budget
    return floor_to_grid(budget, max(1, getattr(cfg, "granularity", 1)))
