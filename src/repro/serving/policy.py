"""Scheduling policy layer: WHAT to run next, separated from the HOW.

The scheduler (serving/scheduler.py) owns the mechanics — block
reservation, prefix matching, lifecycle transitions, suspend/resume — and
delegates every ordering/preemption CHOICE to a ``SchedPolicy``:

  * ``order_admission``  which waiting/suspended requests to try to admit,
    in which order, and whether a blocked candidate blocks everyone behind
    it (``strict`` — head-of-line) or is skipped.
  * ``order_prefill``    which admitted requests' prompt chunks to pack
    into the next engine step while the token budget lasts.
  * ``pick_victim``      which running decode (if any) to SUSPEND so a
    blocked candidate can be admitted: the victim's blocks demote to the
    host tier (or park on the LRU list), its slot frees, and it is resumed
    later through the prefix-cache promote machinery.

Two implementations:

``FCFSPolicy`` reproduces the pre-policy scheduler token- and
step-identically: arrival order, strict head-of-line blocking, never
preempts.  It is the default.

``SLOPolicy`` targets latency SLOs under multi-tenant load:

  * admission is earliest-deadline-first over the per-request TTFT
    deadline (``Request.ttft_deadline_s``, absolute deadline =
    ``arrival_s + ttft_deadline_s``), ties broken by priority (higher
    first) and then per-tenant weighted fairness (tenants that have
    consumed less service per unit weight go first); no head-of-line
    blocking — a blocked candidate is skipped, not waited on.
  * prefill packing follows the same urgency order, so a
    deadline-at-risk request's chunks pre-empt the token budget.
  * when a deadline-carrying candidate is blocked on pool/slot capacity
    and its deadline is within ``risk_frac`` of expiring, the policy
    names a victim among the running decodes — lowest priority first,
    then the tenant with the most service per weight, then the decode
    that has run longest — and the scheduler suspends it.  A victim is
    only chosen whose own deadline is STRICTLY later than the
    candidate's (ties preempting each other would never terminate).

Fairness accounting is virtual-time-style: the scheduler reports every
processed token via ``note_work`` and the policy accumulates
``service / weight`` per tenant; ordering prefers the smallest.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.serving import request as rq

_INF = float("inf")


class SchedPolicy:
    """Interface + FCFS-shaped defaults.  Stateless unless a subclass
    keeps fairness accounting; one policy instance belongs to one
    scheduler."""

    name = "base"
    #: a blocked admission candidate blocks everything behind it
    strict = True
    #: the scheduler must size block tables for suspend/resume worst cases
    may_preempt = False

    def order_admission(self, suspended: Sequence["rq.Request"],
                        waiting: Sequence["rq.Request"],
                        now: float) -> List["rq.Request"]:
        """Candidates for (re-)admission this step, most urgent first.
        Suspended requests come back through the same gate — their work is
        sunk, so the defaults resume them before admitting new work."""
        return list(suspended) + list(waiting)

    def order_prefill(self, prefilling: Sequence["rq.Request"],
                      now: float) -> List["rq.Request"]:
        """Order in which admitted requests' prompt chunks are packed."""
        return list(prefilling)

    def pick_victim(self, blocked: "rq.Request",
                    decoding: Sequence["rq.Request"],
                    now: float) -> Optional["rq.Request"]:
        """A running decode to suspend so ``blocked`` can admit, or None
        (give up — ``blocked`` waits)."""
        return None

    def note_work(self, r: "rq.Request", tokens: int) -> None:
        """The scheduler processed ``tokens`` prompt/decode tokens for
        ``r`` (fairness accounting hook)."""


class FCFSPolicy(SchedPolicy):
    """Arrival order, head-of-line blocking, no preemption — byte-for-byte
    the pre-policy scheduler's behavior (tests/test_scheduler.py's parity
    suite runs through this path)."""

    name = "fcfs"


class SLOPolicy(SchedPolicy):
    """EDF admission + per-tenant weighted fairness + decode preemption.

    ``weights`` maps tenant -> relative share (default 1.0 each).
    ``risk_frac``: a blocked candidate may trigger preemption once
    ``now >= arrival + risk_frac * ttft_deadline`` (0.0 = preempt as soon
    as a deadline-carrying request is blocked; 1.0 = only after the
    deadline has already passed).
    """

    name = "slo"
    strict = False
    may_preempt = True

    def __init__(self, weights: Optional[Dict[str, float]] = None,
                 risk_frac: float = 0.25, preempt: bool = True):
        self.weights = dict(weights or {})
        self.risk_frac = float(risk_frac)
        self.may_preempt = bool(preempt)
        self._service: Dict[str, float] = {}    # tenant -> service/weight

    # ---- fairness accounting --------------------------------------------
    def _weight(self, tenant: str) -> float:
        return max(1e-9, float(self.weights.get(tenant, 1.0)))

    def _vt(self, tenant: str) -> float:
        return self._service.get(tenant, 0.0)

    def note_work(self, r: "rq.Request", tokens: int) -> None:
        t = r.tenant
        self._service[t] = self._vt(t) + tokens / self._weight(t)

    # ---- ordering --------------------------------------------------------
    @staticmethod
    def deadline(r: "rq.Request") -> float:
        """Absolute TTFT deadline (inf when the request carries none)."""
        return (_INF if r.ttft_deadline_s is None
                else r.arrival_s + r.ttft_deadline_s)

    def _urgency(self, r: "rq.Request"):
        return (self.deadline(r), -r.priority, self._vt(r.tenant),
                r.arrival_s, r.rid)

    def order_admission(self, suspended, waiting, now):
        return sorted(list(suspended) + list(waiting), key=self._urgency)

    def order_prefill(self, prefilling, now):
        return sorted(prefilling, key=self._urgency)

    # ---- preemption ------------------------------------------------------
    def at_risk(self, r: "rq.Request", now: float) -> bool:
        return (r.ttft_deadline_s is not None
                and now >= r.arrival_s + self.risk_frac * r.ttft_deadline_s)

    def pick_victim(self, blocked, decoding, now):
        if not self.may_preempt or not decoding \
                or not self.at_risk(blocked, now):
            return None
        bd = self.deadline(blocked)
        # STRICTLY later deadline only: allowing equal deadlines would let
        # two requests suspend each other in alternation forever (the
        # well-founded ordering is what guarantees admit() terminates)
        cands = [v for v in decoding if self.deadline(v) > bd]
        if not cands:
            return None
        # sacrifice the least urgent work: lowest priority, then the
        # most-served tenant, then the decode that has run longest (most
        # sunk KV — but also the one most likely to keep holding blocks)
        return max(cands, key=lambda v: (-v.priority, self._vt(v.tenant),
                                         len(v.out), -v.arrival_s, v.rid))


_POLICIES = {"fcfs": FCFSPolicy, "slo": SLOPolicy}


def resolve_policy(policy) -> SchedPolicy:
    """None | name | instance -> a policy instance (fresh per scheduler:
    SLOPolicy carries per-run fairness state)."""
    if policy is None:
        return FCFSPolicy()
    if isinstance(policy, SchedPolicy):
        return policy
    if isinstance(policy, str):
        try:
            return _POLICIES[policy]()
        except KeyError:
            raise ValueError(
                f"unknown scheduling policy {policy!r}; "
                f"choose from {sorted(_POLICIES)}") from None
    raise TypeError(f"policy must be None, a name or a SchedPolicy, "
                    f"got {type(policy).__name__}")
