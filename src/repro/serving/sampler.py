"""Token sampling: greedy / temperature / top-k / top-p."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0        # 0 = greedy
    top_k: Optional[int] = None
    top_p: Optional[float] = None


def sample(logits, key, cfg: SamplerConfig):
    """logits: (b, V) fp32 -> (b,) int32."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lg = logits / cfg.temperature
    if cfg.top_k is not None:
        kth = jax.lax.top_k(lg, cfg.top_k)[0][:, -1:]
        lg = jnp.where(lg < kth, -jnp.inf, lg)
    if cfg.top_p is not None:
        srt = jnp.sort(lg, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(srt, axis=-1)
        csum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(csum < cfg.top_p, axis=-1, keepdims=True)
        kth = jnp.take_along_axis(srt, cutoff_idx, axis=-1)
        lg = jnp.where(lg < kth, -jnp.inf, lg)
    return jax.random.categorical(key, lg, axis=-1).astype(jnp.int32)
