"""Token sampling: greedy / temperature / top-k / top-p."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 0.0        # 0 = greedy
    top_k: Optional[int] = None
    top_p: Optional[float] = None


def sample(logits, key, cfg: SamplerConfig):
    """logits: (b, V) fp32 -> (b,) int32."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lg = logits / cfg.temperature
    if cfg.top_k is not None:
        kth = jax.lax.top_k(lg, cfg.top_k)[0][:, -1:]
        lg = jnp.where(lg < kth, -jnp.inf, lg)
    if cfg.top_p is not None:
        srt = jnp.sort(lg, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(srt, axis=-1)
        csum = jnp.cumsum(probs, axis=-1)
        # index of the token whose cumulative mass crosses p (kept).  Two
        # degenerate edges: csum[0] >= p gives cutoff 0 — the nucleus is
        # "empty" but the max-prob token must always survive — and float
        # rounding can leave csum[-1] < p, pushing the count to V; clamp it.
        cutoff_idx = jnp.minimum(jnp.sum(csum < cfg.top_p, axis=-1,
                                         keepdims=True), lg.shape[-1] - 1)
        kth = jnp.take_along_axis(srt, cutoff_idx, axis=-1)
        keep = (lg >= kth) | (lg >= jnp.max(lg, axis=-1, keepdims=True))
        lg = jnp.where(keep, lg, -jnp.inf)
    return jax.random.categorical(key, lg, axis=-1).astype(jnp.int32)
