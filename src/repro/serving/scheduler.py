"""Continuous-batching scheduler: MECHANICS only — ordering and preemption
choices live in a pluggable ``SchedPolicy`` (serving/policy.py).

Per engine step:

  1. ``admit``: the policy orders the waiting + suspended requests; each
     candidate is admitted while (a) a batch slot is free (active requests
     < ``max_decode_batch``) and (b) the pool can reserve its blocks.
     Reservation is conservative — ceil((padded_prefill_span + max_new) /
     block_size) blocks up front — so a running request can never OOM
     mid-flight.  A blocked candidate either blocks everything behind it
     (``policy.strict``, FCFS head-of-line) or is skipped (SLO); the
     policy may instead name a running decode to SUSPEND (see below) and
     retry.  With prefix caching on, admission first matches the
     request's longest cached prefix (full blocks + COW tail, floored to
     ``prefix_align``), pins the shared blocks into its table and admits
     it with only the uncached suffix as prefill work (``n_prefilled``
     starts at the hit length).
  2. ``pack_prefill``: pending prompt chunks in policy order, one B_CP
     chunk per request (chunks of one request are sequential), charging
     the chunk's REAL token count (rounded to ``token_grid``) against
     ``max_prefill_tokens`` and capping rows at the compiled
     ``max_prefill_rows`` geometry.
  3. ``pack_decode``: ALL active decode requests (bounded by admission).

Preemption (suspend/resume): suspending a DECODE request registers its
blocks — prompt AND generated KV — in the pool's content-addressed prefix
cache and frees them (demoted straight to the host tier when one exists,
parked on the LRU list otherwise), freeing its batch slot.  Resume is
re-admission through the same prefix-match machinery: the preserved KV
comes back as a cache hit covering ``Request.kv_len`` tokens, and any
suffix lost to eviction in between is replayed in prefill chunks
(``resume_len``) before decoding continues.  With the KV intact, a
suspend -> resume round trip is token-identical to running uninterrupted;
a replay after cache loss is exact for ``full`` (chunking-invariant) and
a documented approximation for selection methods (the replayed chunks
re-select over the generated region).

Completion (EOS / stop / length) frees the request's blocks; registered
prefix blocks stay resident (LRU) until memory pressure.

``prefix_align`` guards exactness: a cache hit replays KV the donor
computed with chunk boundaries at multiples of B_CP starting from 0.
Selection-based methods (QUOKA & baselines) score per chunk, so their
outputs are only reproducible when the sharer's suffix chunks land on the
same grid — hits must be floored to a chunk multiple.  Dense attention is
chunking-invariant, so ``full`` can share at token granularity (COW tails).
The scheduler is pure host-side policy; device work happens in the engine.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.obs import registry as obs_reg
from repro.serving import request as rq
from repro.serving.policy import SchedPolicy, resolve_policy
from repro.serving.pool import (PagedKVCache, _chain_hashes,
                                blocks_for_request, blocks_for_resume,
                                max_blocks_bound)


class Scheduler:
    def __init__(self, pool: PagedKVCache, chunk_size: int,
                 max_prefill_tokens: int, max_decode_batch: int,
                 prefix_cache: bool = False, prefix_align: int = 1,
                 registry=None, policy=None,
                 max_prefill_rows: Optional[int] = None,
                 token_grid: int = 1):
        assert max_prefill_tokens >= chunk_size, \
            "max_prefill_tokens must fit at least one chunk"
        # lifecycle counters (obs/registry.py): submitted / admitted /
        # prefix_hit_* / hit_degraded / preemptions / resumes / finished
        # under sched/.  The default NULL registry makes every count() a
        # no-op; the plain-int twins below feed ServeResult either way.
        self.reg = registry if registry is not None else obs_reg.NULL
        self.pool = pool
        self.policy: SchedPolicy = resolve_policy(policy)
        self.chunk_size = int(chunk_size)
        self.max_prefill_tokens = int(max_prefill_tokens)
        # compiled prefill-row geometry: how many chunk rows one step can
        # carry.  Defaults to the full-chunk capacity of the token budget;
        # a larger value lets short tail chunks — charged their REAL
        # length — pack together instead of each eating a whole padded
        # chunk of budget (the pack_prefill tail-charging fix)
        self.max_prefill_rows = int(
            max_prefill_rows if max_prefill_rows is not None
            else max(1, self.max_prefill_tokens // self.chunk_size))
        self.token_grid = max(1, int(token_grid))
        self.max_decode_batch = int(max_decode_batch)
        self.prefix_cache = bool(prefix_cache)
        self.prefix_align = max(1, int(prefix_align))
        self.waiting: List[rq.Request] = []
        self.prefilling: List[rq.Request] = []
        self.decoding: List[rq.Request] = []
        self.suspended: List[rq.Request] = []
        self.done: List[rq.Request] = []
        # plain-int counters (ServeResult fields; registry mirrors them)
        self.preemptions = 0
        self.resumes = 0
        self.resume_replays = 0
        self.deadline_misses = 0
        # rid -> precomputed _chain_hashes of the prompt: admit() re-matches
        # a pool-blocked head request EVERY engine step, and O(prompt_len)
        # re-hashing per step would tax every interleaved decode step
        self._chain: Dict[int, List[int]] = {}
        # rid -> chain hashes of the SUSPENDED kv sequence (prompt +
        # generated); invalidated on suspend — kv grows between rounds
        self._rchain: Dict[int, List[int]] = {}

    # ------------------------------------------------------------------
    def blocks_needed(self, r: rq.Request, cached_len: int = 0) -> int:
        return blocks_for_request(r.prompt_len, r.max_new, self.chunk_size,
                                  self.pool.block_size, cached_len=cached_len)

    def add(self, r: rq.Request) -> None:
        n = self.blocks_needed(r)
        if self.policy.may_preempt:
            # a preemptible request must also fit its worst-case RESUME
            # reservation, or a suspended request could deadlock waiting
            # on a pool it can never re-enter
            n = max(n, max_blocks_bound(
                r.prompt_len, r.max_new, self.chunk_size,
                self.pool.block_size, align=self.prefix_align,
                preempt=True))
        if n > self.pool.num_blocks:
            raise ValueError(
                f"request {r.rid} needs {n} blocks > pool size "
                f"{self.pool.num_blocks}; it can never be admitted")
        # reset ALL runtime state so a Request object can be re-served
        # (warmup-then-measure traces); stale n_prefilled/out would make a
        # re-served request complete instantly with the previous run's tokens
        r.status = rq.WAITING
        r.n_prefilled = 0
        r.cached_len = 0
        r.out = []
        r.ttft_s = None
        r.done_s = None
        r.preemptions = 0
        r.resume_len = 0
        self._chain.pop(r.rid, None)       # rid may carry new tokens
        self._rchain.pop(r.rid, None)
        self.waiting.append(r)
        self.reg.count("sched/submitted")

    def pending(self) -> bool:
        return bool(self.waiting or self.prefilling or self.decoding
                    or self.suspended)

    @property
    def n_active(self) -> int:
        return len(self.prefilling) + len(self.decoding)

    # ------------------------------------------------------------------
    def _match(self, r: rq.Request) -> Tuple[int, List,
                                             Optional[Tuple]]:
        """Longest usable cached prefix of ``r``: (cached_len, shared full
        blocks, cow) with cached_len floored to ``prefix_align`` and capped
        at prompt_len - 1 (at least one token must be recomputed to produce
        the first-token logits).  With the host tier on, shared entries and
        the COW source may be ``("host", slot)`` — demoted blocks the pool
        promotes at alloc time (pool.alloc_prefix); they pass through here
        opaquely."""
        chain = self._chain.get(r.rid)
        if chain is None:
            chain = self._chain[r.rid] = _chain_hashes(
                r.tokens, self.pool.block_size)
        fulls, tail = self.pool.match_prefix(r.tokens, chain=chain)
        bs = self.pool.block_size
        matched = len(fulls) * bs + (tail[1] if tail else 0)
        cached = (min(matched, r.prompt_len - 1)
                  // self.prefix_align) * self.prefix_align
        return self._hit(cached, fulls, tail)

    def _hit(self, cached: int, fulls: List, tail) -> Tuple[int, List,
                                                            Optional[Tuple]]:
        """(cached, shared full blocks, cow) for a hit of ``cached`` tokens
        out of a ``match_prefix`` result."""
        if cached <= 0:
            return 0, [], None
        bs = self.pool.block_size
        n_shared, keep = divmod(cached, bs)
        shared = fulls[:n_shared]
        cow = None
        if keep:
            src = fulls[n_shared] if n_shared < len(fulls) else tail[0]
            cow = (src, keep)
        return cached, shared, cow

    def admit(self, now: float = 0.0) -> List[rq.Request]:
        """(Re-)admit requests in policy order.  A blocked candidate may
        trigger a preemption (``policy.pick_victim`` names a running
        decode to suspend) and is retried; under a strict policy it
        blocks everything behind it instead."""
        admitted = []
        # preemption cap per admit() call: the policy's strict-deadline
        # victim ordering already rules out suspend cycles, but a buggy
        # policy must degrade to "stops preempting", not an infinite loop
        preempts_left = len(self.decoding) + len(self.suspended) + 1
        while self.waiting or self.suspended:
            progressed = False
            order = self.policy.order_admission(self.suspended,
                                                self.waiting, now)
            if self.n_active >= self.max_decode_batch:
                # batch slots exhausted: only a preemption can make room
                for r in order:
                    victim = (self.policy.pick_victim(r, self.decoding, now)
                              if preempts_left > 0 else None)
                    if victim is not None:
                        self.suspend(victim, now)
                        preempts_left -= 1
                        progressed = True
                        break
                    if self.policy.strict:
                        break
                if not progressed:
                    break
                continue
            for r in order:
                if self._try_admit(r, now):
                    admitted.append(r)
                    progressed = True
                    break
                victim = (self.policy.pick_victim(r, self.decoding, now)
                          if preempts_left > 0 else None)
                if victim is not None:
                    self.suspend(victim, now)
                    preempts_left -= 1
                    progressed = True      # retry r against the freed pool
                    break
                if self.policy.strict:
                    break                  # FCFS: no skipping the head
            if not progressed:
                break
        return admitted

    def _try_admit(self, r: rq.Request, now: float) -> bool:
        if r.status == rq.SUSPENDED:
            return self._try_resume(r, now)
        pool = self.pool
        cached, shared, cow = (self._match(r) if self.prefix_cache
                               else (0, [], None))
        n = self.blocks_needed(r, cached_len=cached)
        # host-tier matches (("host", slot) entries) are cached WORK —
        # the prefill they save is saved either way — but not cached
        # BLOCKS: each promotion consumes a fresh device block, so only
        # device-resident shared blocks reduce the fresh-block demand
        # (and only device ids can be eviction-protected)
        dev_shared = [b for b in shared if not isinstance(b, tuple)]
        protect = dev_shared + \
            ([cow[0]] if cow and not isinstance(cow[0], tuple) else [])
        if cached and not pool.can_alloc(n - len(dev_shared),
                                         exclude=protect):
            # a hit can demand MORE of the pool than a cold admit: a
            # token-granularity hit shifts the chunk grid (up to one
            # extra block of padding) and its shared/COW-source blocks
            # are protected from eviction.  Degrade to a cold admit
            # rather than stalling the candidate on a pool the request
            # fits cold.
            cached, shared, cow, protect = 0, [], None, []
            dev_shared = []
            n = self.blocks_needed(r)
            self.reg.count("sched/hit_degraded")
        if not pool.can_alloc(n - len(dev_shared), exclude=protect):
            return False
        n_promote = len(shared) - len(dev_shared)
        if n_promote:
            self.reg.count("sched/promoted_blocks", float(n_promote))
        pool.alloc_prefix(r.rid, n, shared, cow)
        pool.lookups += 1
        pool.prompt_tokens += r.prompt_len
        if cached:
            pool.hit_requests += 1
            pool.hit_tokens += cached
        r.cached_len = cached
        r.n_prefilled = cached         # prefill only the uncached suffix
        r.status = rq.PREFILL
        self.waiting.remove(r)
        self.prefilling.append(r)
        self.reg.count("sched/admitted")
        if cached:
            self.reg.count("sched/prefix_hit_requests")
            self.reg.count("sched/prefix_hit_tokens", float(cached))
        return True

    # ---- suspend / resume ------------------------------------------------
    def suspend(self, r: rq.Request, now: float) -> None:
        """Preempt a DECODE request: its KV blocks are content-registered
        and released (demoted to the host tier when one exists), its batch
        slot freed.  The request parks in ``suspended`` until the policy
        re-admits it."""
        assert r.status == rq.DECODE, \
            f"only decoding requests are preemptible (rid {r.rid} is " \
            f"{r.status})"
        seq_kv = r.seq_tokens()[:r.kv_len]
        with self.reg.span("sched/suspend", rid=r.rid):
            _, demoted = self.pool.suspend(r.rid, seq_kv)
        self.decoding.remove(r)
        r.status = rq.SUSPENDED
        r.preemptions += 1
        self.suspended.append(r)
        self._rchain.pop(r.rid, None)     # kv grew since any prior suspend
        self.preemptions += 1
        self.reg.count("sched/preemptions")
        self.reg.count(f"tenant/{r.tenant}/preemptions")
        if demoted:
            self.reg.count("sched/suspend_demoted_blocks", float(demoted))

    def _try_resume(self, r: rq.Request, now: float) -> bool:
        """Re-admit a suspended request: match the preserved prompt +
        generated KV as a prefix hit; a suffix lost to eviction since the
        suspend is replayed in prefill chunks (``resume_len``) before
        decoding continues."""
        pool = self.pool
        kv = r.seq_tokens()[:r.kv_len]
        chain = self._rchain.get(r.rid)
        if chain is None:
            chain = self._rchain[r.rid] = _chain_hashes(kv, pool.block_size)
        fulls, tail = pool.match_prefix(kv, chain=chain)
        matched = len(fulls) * pool.block_size + (tail[1] if tail else 0)
        cached = min(matched, r.kv_len)
        if cached < r.kv_len:
            # replay chunks must land on the align grid (selection methods
            # are chunk-grid-sensitive; ``full`` shares at any offset)
            cached = (cached // self.prefix_align) * self.prefix_align
        cached, shared, cow = self._hit(cached, fulls, tail)
        n = blocks_for_resume(r.kv_len, r.prompt_len, r.max_new,
                              self.chunk_size, pool.block_size, cached)
        dev_shared = [b for b in shared if not isinstance(b, tuple)]
        protect = dev_shared + \
            ([cow[0]] if cow and not isinstance(cow[0], tuple) else [])
        if cached and not pool.can_alloc(n - len(dev_shared),
                                         exclude=protect):
            # same degrade as fresh admission: a hit's protected blocks can
            # exceed what a hit-free reservation needs; fall back to a full
            # replay-from-scratch resume rather than stalling (the preempt
            # admission bound guarantees the cold reservation fits)
            cached, shared, cow = 0, [], None
            dev_shared, protect = [], []
            n = blocks_for_resume(r.kv_len, r.prompt_len, r.max_new,
                                  self.chunk_size, pool.block_size, 0)
            self.reg.count("sched/hit_degraded")
        if not pool.can_alloc(n - len(dev_shared), exclude=protect):
            return False
        with self.reg.span("sched/resume", rid=r.rid):
            pool.alloc_prefix(r.rid, n, shared, cow)
        self._rchain.pop(r.rid, None)
        self.suspended.remove(r)
        self.resumes += 1
        self.reg.count("sched/resumes")
        if cached >= r.kv_len:
            r.resume_len = 0
            r.n_prefilled = r.prompt_len
            r.status = rq.DECODE
            self.decoding.append(r)
        else:
            r.resume_len = r.kv_len
            r.n_prefilled = cached
            r.status = rq.PREFILL
            self.prefilling.append(r)
            self.resume_replays += 1
            self.reg.count("sched/resume_replay_tokens",
                           float(r.kv_len - cached))
        return True

    # ------------------------------------------------------------------
    def pack_prefill(self, now: float = 0.0
                     ) -> List[Tuple[rq.Request, "object", int, int]]:
        """[(request, chunk_tokens, start, valid_len)] — one chunk per
        request, in policy order, until the token budget or the compiled
        row geometry is spent.  A chunk charges its REAL valid length
        (rounded up to ``token_grid``, capped at the chunk width) against
        ``max_prefill_tokens`` — a short tail no longer eats a whole
        padded chunk of budget, so tails pack together when
        ``max_prefill_rows`` leaves room."""
        rows = []
        budget = self.max_prefill_tokens
        g = self.token_grid
        for r in self.policy.order_prefill(list(self.prefilling), now):
            if len(rows) >= self.max_prefill_rows:
                break
            vnext = min(self.chunk_size, r.prefill_target - r.n_prefilled)
            charge = min(self.chunk_size, -(-vnext // g) * g)
            if charge > budget:
                break
            tok, start, vlen = r.next_chunk(self.chunk_size)
            rows.append((r, tok, start, vlen))
            budget -= charge
        return rows

    def note_prefilled(self, r: rq.Request, vlen: int,
                       first_token: Optional[int],
                       now: float) -> Optional[int]:
        """Returns the emitted first token (prompt prefill just completed)
        or None (mid-prompt, or a resume replay — whose final chunk
        re-predicts the already-emitted ``out[-1]`` and is discarded)."""
        r.n_prefilled += vlen
        self.policy.note_work(r, vlen)
        if r.n_prefilled < r.prefill_target:
            return None
        if r.resume_len:
            # resume replay complete: decoding continues from out[-1]
            r.resume_len = 0
            r.status = rq.DECODE
            self.prefilling.remove(r)
            self.decoding.append(r)
            return None
        if self.prefix_cache:
            self.pool.register_prefix(r.rid, r.tokens,
                                      chain=self._chain.pop(r.rid, None))
        r.status = rq.DECODE
        r.out.append(int(first_token))
        r.ttft_s = now - r.arrival_s
        if r.ttft_deadline_s is not None:
            if r.ttft_s > r.ttft_deadline_s:
                self.deadline_misses += 1
                self.reg.count("serve/deadline_miss")
                self.reg.count(f"tenant/{r.tenant}/deadline_miss")
            else:
                self.reg.count(f"tenant/{r.tenant}/deadline_met")
        self.prefilling.remove(r)
        if r.finished():               # max_new == 1 or instant EOS
            self._finish(r, now)
        else:
            self.decoding.append(r)
        return r.out[-1]

    def pack_decode(self) -> List[rq.Request]:
        return list(self.decoding)

    def note_decoded(self, r: rq.Request, token: int, now: float) -> int:
        r.out.append(int(token))
        self.policy.note_work(r, 1)
        if r.finished():
            self.decoding.remove(r)
            self._finish(r, now)
        return r.out[-1]

    def _finish(self, r: rq.Request, now: float) -> None:
        r.status = rq.DONE
        r.done_s = now
        self.pool.free(r.rid)      # registered prefix blocks stay resident
        self.done.append(r)
        self.reg.count("sched/finished")
        self.reg.count(f"tenant/{r.tenant}/finished")
