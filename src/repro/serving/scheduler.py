"""Continuous-batching scheduler (Sarathi-style chunked-prefill packing).

Policy, per engine step:

  1. ``admit``: WAITING requests move to PREFILL in FCFS order while (a) a
     batch slot is free (active requests < ``max_decode_batch``) and (b)
     the pool can reserve their blocks.  Reservation is conservative —
     ceil((padded_prompt + max_new) / block_size) blocks up front — so a
     running request can never OOM mid-flight (no preemption needed).
     Head-of-line blocking is deliberate: FCFS keeps TTFT fair.
  2. ``pack_prefill``: up to ``max_prefill_tokens`` worth of pending prompt
     chunks, one B_CP chunk per request (chunks of one request are
     sequential — its next chunk needs this one's KV).
  3. ``pack_decode``: ALL active decode requests (bounded by admission).

Completion (EOS / stop / length) frees the request's blocks immediately.
The scheduler is pure host-side policy; device work happens in the engine.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

from repro.serving import request as rq
from repro.serving.pool import PagedKVCache, blocks_for_request


class Scheduler:
    def __init__(self, pool: PagedKVCache, chunk_size: int,
                 max_prefill_tokens: int, max_decode_batch: int):
        assert max_prefill_tokens >= chunk_size, \
            "max_prefill_tokens must fit at least one chunk"
        self.pool = pool
        self.chunk_size = int(chunk_size)
        self.max_prefill_tokens = int(max_prefill_tokens)
        self.max_decode_batch = int(max_decode_batch)
        self.waiting: List[rq.Request] = []
        self.prefilling: List[rq.Request] = []
        self.decoding: List[rq.Request] = []
        self.done: List[rq.Request] = []

    # ------------------------------------------------------------------
    def blocks_needed(self, r: rq.Request) -> int:
        return blocks_for_request(r.prompt_len, r.max_new, self.chunk_size,
                                  self.pool.block_size)

    def add(self, r: rq.Request) -> None:
        n = self.blocks_needed(r)
        if n > self.pool.num_blocks:
            raise ValueError(
                f"request {r.rid} needs {n} blocks > pool size "
                f"{self.pool.num_blocks}; it can never be admitted")
        # reset ALL runtime state so a Request object can be re-served
        # (warmup-then-measure traces); stale n_prefilled/out would make a
        # re-served request complete instantly with the previous run's tokens
        r.status = rq.WAITING
        r.n_prefilled = 0
        r.out = []
        r.ttft_s = None
        r.done_s = None
        self.waiting.append(r)

    def pending(self) -> bool:
        return bool(self.waiting or self.prefilling or self.decoding)

    @property
    def n_active(self) -> int:
        return len(self.prefilling) + len(self.decoding)

    # ------------------------------------------------------------------
    def admit(self) -> List[rq.Request]:
        admitted = []
        while self.waiting and self.n_active < self.max_decode_batch:
            r = self.waiting[0]
            n = self.blocks_needed(r)
            if not self.pool.can_alloc(n):
                break                      # FCFS: no skipping the head
            self.pool.alloc(r.rid, n)
            r.status = rq.PREFILL
            self.prefilling.append(self.waiting.pop(0))
            admitted.append(r)
        return admitted

    def pack_prefill(self) -> List[Tuple[rq.Request, "object", int, int]]:
        """[(request, chunk_tokens, start, valid_len)] — one chunk per
        request, FCFS, until the token budget is spent."""
        rows = []
        budget = self.max_prefill_tokens
        for r in self.prefilling:
            if budget < self.chunk_size:
                break
            tok, start, vlen = r.next_chunk(self.chunk_size)
            rows.append((r, tok, start, vlen))
            budget -= self.chunk_size
        return rows

    def note_prefilled(self, r: rq.Request, vlen: int,
                       first_token: Optional[int], now: float) -> None:
        r.n_prefilled += vlen
        if r.n_prefilled >= r.prompt_len:
            r.status = rq.DECODE
            r.out.append(int(first_token))
            r.ttft_s = now - r.arrival_s
            self.prefilling.remove(r)
            if r.finished():               # max_new == 1 or instant EOS
                self._finish(r, now)
            else:
                self.decoding.append(r)

    def pack_decode(self) -> List[rq.Request]:
        return list(self.decoding)

    def note_decoded(self, r: rq.Request, token: int, now: float) -> None:
        r.out.append(int(token))
        if r.finished():
            self.decoding.remove(r)
            self._finish(r, now)

    def _finish(self, r: rq.Request, now: float) -> None:
        r.status = rq.DONE
        r.done_s = now
        self.pool.free(r.rid)              # eviction: blocks back to the pool
        self.done.append(r)
