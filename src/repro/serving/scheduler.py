"""Continuous-batching scheduler (Sarathi-style chunked-prefill packing).

Policy, per engine step:

  1. ``admit``: WAITING requests move to PREFILL in FCFS order while (a) a
     batch slot is free (active requests < ``max_decode_batch``) and (b)
     the pool can reserve their blocks.  Reservation is conservative —
     ceil((padded_prefill_span + max_new) / block_size) blocks up front —
     so a running request can never OOM mid-flight (no preemption needed).
     Head-of-line blocking is deliberate: FCFS keeps TTFT fair.
     With prefix caching on, admission first matches the request's longest
     cached prefix (full blocks + COW tail, floored to ``prefix_align``),
     pins the shared blocks into its table and admits it with only the
     uncached suffix as prefill work (``n_prefilled`` starts at the hit
     length; the per-request ``chunk_start`` plumbing does the rest).
  2. ``pack_prefill``: up to ``max_prefill_tokens`` worth of pending prompt
     chunks, one B_CP chunk per request (chunks of one request are
     sequential — its next chunk needs this one's KV).
  3. ``pack_decode``: ALL active decode requests (bounded by admission).

Completion (EOS / stop / length) frees the request's blocks; registered
prefix blocks stay resident (LRU) until memory pressure.

``prefix_align`` guards exactness: a cache hit replays KV the donor
computed with chunk boundaries at multiples of B_CP starting from 0.
Selection-based methods (QUOKA & baselines) score per chunk, so their
outputs are only reproducible when the sharer's suffix chunks land on the
same grid — hits must be floored to a chunk multiple.  Dense attention is
chunking-invariant, so ``full`` can share at token granularity (COW tails).
The scheduler is pure host-side policy; device work happens in the engine.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.obs import registry as obs_reg
from repro.serving import request as rq
from repro.serving.pool import (PagedKVCache, _chain_hashes,
                                blocks_for_request)


class Scheduler:
    def __init__(self, pool: PagedKVCache, chunk_size: int,
                 max_prefill_tokens: int, max_decode_batch: int,
                 prefix_cache: bool = False, prefix_align: int = 1,
                 registry=None):
        assert max_prefill_tokens >= chunk_size, \
            "max_prefill_tokens must fit at least one chunk"
        # lifecycle counters (obs/registry.py): submitted / admitted /
        # prefix_hit_* / hit_degraded / finished under sched/.  The default
        # NULL registry makes every count() a no-op.
        self.reg = registry if registry is not None else obs_reg.NULL
        self.pool = pool
        self.chunk_size = int(chunk_size)
        self.max_prefill_tokens = int(max_prefill_tokens)
        self.max_decode_batch = int(max_decode_batch)
        self.prefix_cache = bool(prefix_cache)
        self.prefix_align = max(1, int(prefix_align))
        self.waiting: List[rq.Request] = []
        self.prefilling: List[rq.Request] = []
        self.decoding: List[rq.Request] = []
        self.done: List[rq.Request] = []
        # rid -> precomputed _chain_hashes of the prompt: admit() re-matches
        # a pool-blocked head request EVERY engine step, and O(prompt_len)
        # re-hashing per step would tax every interleaved decode step
        self._chain: Dict[int, List[int]] = {}

    # ------------------------------------------------------------------
    def blocks_needed(self, r: rq.Request, cached_len: int = 0) -> int:
        return blocks_for_request(r.prompt_len, r.max_new, self.chunk_size,
                                  self.pool.block_size, cached_len=cached_len)

    def add(self, r: rq.Request) -> None:
        n = self.blocks_needed(r)
        if n > self.pool.num_blocks:
            raise ValueError(
                f"request {r.rid} needs {n} blocks > pool size "
                f"{self.pool.num_blocks}; it can never be admitted")
        # reset ALL runtime state so a Request object can be re-served
        # (warmup-then-measure traces); stale n_prefilled/out would make a
        # re-served request complete instantly with the previous run's tokens
        r.status = rq.WAITING
        r.n_prefilled = 0
        r.cached_len = 0
        r.out = []
        r.ttft_s = None
        r.done_s = None
        self._chain.pop(r.rid, None)       # rid may carry new tokens
        self.waiting.append(r)
        self.reg.count("sched/submitted")

    def pending(self) -> bool:
        return bool(self.waiting or self.prefilling or self.decoding)

    @property
    def n_active(self) -> int:
        return len(self.prefilling) + len(self.decoding)

    # ------------------------------------------------------------------
    def _match(self, r: rq.Request) -> Tuple[int, List,
                                             Optional[Tuple]]:
        """Longest usable cached prefix of ``r``: (cached_len, shared full
        blocks, cow) with cached_len floored to ``prefix_align`` and capped
        at prompt_len - 1 (at least one token must be recomputed to produce
        the first-token logits).  With the host tier on, shared entries and
        the COW source may be ``("host", slot)`` — demoted blocks the pool
        promotes at alloc time (pool.alloc_prefix); they pass through here
        opaquely."""
        chain = self._chain.get(r.rid)
        if chain is None:
            chain = self._chain[r.rid] = _chain_hashes(
                r.tokens, self.pool.block_size)
        fulls, tail = self.pool.match_prefix(r.tokens, chain=chain)
        bs = self.pool.block_size
        matched = len(fulls) * bs + (tail[1] if tail else 0)
        cached = (min(matched, r.prompt_len - 1)
                  // self.prefix_align) * self.prefix_align
        if cached <= 0:
            return 0, [], None
        n_shared, keep = divmod(cached, bs)
        shared = fulls[:n_shared]
        cow = None
        if keep:
            src = fulls[n_shared] if n_shared < len(fulls) else tail[0]
            cow = (src, keep)
        return cached, shared, cow

    def admit(self) -> List[rq.Request]:
        admitted = []
        pool = self.pool
        while self.waiting and self.n_active < self.max_decode_batch:
            r = self.waiting[0]
            cached, shared, cow = (self._match(r) if self.prefix_cache
                                   else (0, [], None))
            n = self.blocks_needed(r, cached_len=cached)
            # host-tier matches (("host", slot) entries) are cached WORK —
            # the prefill they save is saved either way — but not cached
            # BLOCKS: each promotion consumes a fresh device block, so only
            # device-resident shared blocks reduce the fresh-block demand
            # (and only device ids can be eviction-protected)
            dev_shared = [b for b in shared if not isinstance(b, tuple)]
            protect = dev_shared + \
                ([cow[0]] if cow and not isinstance(cow[0], tuple) else [])
            if cached and not pool.can_alloc(n - len(dev_shared),
                                             exclude=protect):
                # a hit can demand MORE of the pool than a cold admit: a
                # token-granularity hit shifts the chunk grid (up to one
                # extra block of padding) and its shared/COW-source blocks
                # are protected from eviction.  Degrade to a cold admit
                # rather than stalling the FCFS head on a pool the request
                # fits cold.
                cached, shared, cow, protect = 0, [], None, []
                dev_shared = []
                n = self.blocks_needed(r)
                self.reg.count("sched/hit_degraded")
            if not pool.can_alloc(n - len(dev_shared), exclude=protect):
                break                      # FCFS: no skipping the head
            n_promote = len(shared) - len(dev_shared)
            if n_promote:
                self.reg.count("sched/promoted_blocks", float(n_promote))
            pool.alloc_prefix(r.rid, n, shared, cow)
            pool.lookups += 1
            pool.prompt_tokens += r.prompt_len
            if cached:
                pool.hit_requests += 1
                pool.hit_tokens += cached
            r.cached_len = cached
            r.n_prefilled = cached         # prefill only the uncached suffix
            r.status = rq.PREFILL
            self.prefilling.append(self.waiting.pop(0))
            admitted.append(r)
            self.reg.count("sched/admitted")
            if cached:
                self.reg.count("sched/prefix_hit_requests")
                self.reg.count("sched/prefix_hit_tokens", float(cached))
        return admitted

    def pack_prefill(self) -> List[Tuple[rq.Request, "object", int, int]]:
        """[(request, chunk_tokens, start, valid_len)] — one chunk per
        request, FCFS, until the token budget is spent."""
        rows = []
        budget = self.max_prefill_tokens
        for r in self.prefilling:
            if budget < self.chunk_size:
                break
            tok, start, vlen = r.next_chunk(self.chunk_size)
            rows.append((r, tok, start, vlen))
            budget -= self.chunk_size
        return rows

    def note_prefilled(self, r: rq.Request, vlen: int,
                       first_token: Optional[int], now: float) -> None:
        r.n_prefilled += vlen
        if r.n_prefilled >= r.prompt_len:
            if self.prefix_cache:
                self.pool.register_prefix(r.rid, r.tokens,
                                          chain=self._chain.pop(r.rid, None))
            r.status = rq.DECODE
            r.out.append(int(first_token))
            r.ttft_s = now - r.arrival_s
            self.prefilling.remove(r)
            if r.finished():               # max_new == 1 or instant EOS
                self._finish(r, now)
            else:
                self.decoding.append(r)

    def pack_decode(self) -> List[rq.Request]:
        return list(self.decoding)

    def note_decoded(self, r: rq.Request, token: int, now: float) -> None:
        r.out.append(int(token))
        if r.finished():
            self.decoding.remove(r)
            self._finish(r, now)

    def _finish(self, r: rq.Request, now: float) -> None:
        r.status = rq.DONE
        r.done_s = now
        self.pool.free(r.rid)      # registered prefix blocks stay resident
        self.done.append(r)
        self.reg.count("sched/finished")
