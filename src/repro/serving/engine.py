"""Serving engine: batched chunked prefill (QUOKA Algorithm 2) + decode.

One jitted prefill (a lax.scan over B_CP chunks, selection per chunk per
layer) and one jitted decode step (single-query selection).  The engine
reports TTFT / decode throughput — the quantities of paper §4.6.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.serving.sampler import SamplerConfig, sample


@dataclass
class GenerationResult:
    tokens: np.ndarray            # (b, max_new)
    ttft_s: float                 # time to first token (prefill + 1 sample)
    decode_tps: float             # decoded tokens/sec across the batch
    prompt_len: int
    method: str
    backend: str = "auto"         # resolved kernel backend of this run


class Engine:
    def __init__(self, model: Model, params, *, method: Optional[str] = None,
                 backend: Optional[str] = None,
                 sampler: SamplerConfig = SamplerConfig()):
        """``backend`` overrides the kernel backend for this engine
        ("xla" | "pallas_interpret" | "pallas"); None defers to the env /
        ``QuokaConfig.backend`` / hardware resolution (kernels/ops.py)."""
        from repro.kernels import ops as kops
        self.model = model
        self.params = params
        self.method = method or model.cfg.quoka.method
        self.backend = kops.resolve_backend(backend, model.cfg.quoka)
        self.sampler = sampler
        self._prefill = jax.jit(
            lambda p, batch, cache: model.prefill(p, batch, cache,
                                                  self.method,
                                                  backend=self.backend))
        self._decode = jax.jit(
            lambda p, tok, pos, cache: model.decode_step(p, tok, pos, cache,
                                                         self.method,
                                                         backend=self.backend))

    def pad_prompt(self, tokens: np.ndarray) -> np.ndarray:
        """Left-pad to a chunk multiple (pad tokens become ordinary context;
        fine for the synthetic serving demos)."""
        bcp = self.model.cfg.quoka.chunk_size
        t = tokens.shape[1]
        pad = (-t) % bcp
        if pad:
            tokens = np.concatenate(
                [np.zeros((tokens.shape[0], pad), tokens.dtype), tokens], 1)
        return tokens

    def generate(self, batch: Dict, max_new: int, *,
                 key=None) -> GenerationResult:
        """batch['tokens']: (b, T) prompt (T % chunk_size == 0; use
        pad_prompt).  Extra modality inputs pass through."""
        model, params = self.model, self.params
        tokens = np.asarray(batch["tokens"])
        b, t = tokens.shape
        extra = t + (model.cfg.frontend.n_tokens
                     if model.cfg.family == "vlm" else 0)
        cache = model.init_cache(b, extra + max_new)
        key = key if key is not None else jax.random.PRNGKey(0)

        t0 = time.perf_counter()
        logits, cache = self._prefill(params, batch, cache)
        tok = sample(logits, key, self.sampler)
        tok.block_until_ready()
        ttft = time.perf_counter() - t0

        out = [np.asarray(tok)]
        t1 = time.perf_counter()
        pos = extra
        for i in range(max_new - 1):
            key = jax.random.fold_in(key, i)
            logits, cache = self._decode(params, tok, jnp.asarray(pos), cache)
            tok = sample(logits, key, self.sampler)
            out.append(np.asarray(tok))
            pos += 1
        if max_new > 1:
            tok.block_until_ready()
        dt = time.perf_counter() - t1
        tps = (b * (max_new - 1)) / dt if max_new > 1 and dt > 0 else 0.0
        return GenerationResult(tokens=np.stack(out, axis=1), ttft_s=ttft,
                                decode_tps=tps, prompt_len=t,
                                method=self.method, backend=self.backend)
