"""Serving engine: batched chunked prefill (QUOKA Algorithm 2) + decode.

Two serving modes share the model and kernel facade:

  * ``generate`` — one synchronous batch: a jitted scan-prefill followed by
    a Python decode loop (TTFT / decode-throughput probe, paper §4.6).
    Tokens accumulate ON DEVICE; the single host sync happens after the
    loop, so ``decode_tps`` measures compute, not transfers.
  * ``step``/``serve`` — continuous batching: a paged KV pool
    (serving/pool.py) plus a request-lifecycle scheduler
    (serving/scheduler.py) drive two jitted step functions — a mixed
    chunk-prefill step and a batched decode step — that gather each
    request's blocks via its block table into a linear cache view, run the
    existing model/kernel path, and scatter the touched blocks back.
    Prefill chunks of new requests interleave with decode steps of running
    ones (Sarathi-style), which is what chunked prefill exists for.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import Model
from repro.obs import registry as obs_reg
from repro.serving.sampler import SamplerConfig, sample


@dataclass
class GenerationResult:
    tokens: np.ndarray            # (b, max_new)
    ttft_s: float                 # time to first token (prefill + 1 sample)
    decode_tps: float             # decoded tokens/sec across the batch
    prompt_len: int               # TRUE prompt length (pad_prompt padding
                                  # excluded — per-token TTFT normalisation)
    method: str
    backend: str = "auto"         # resolved kernel backend of this run


@dataclass
class ServeState:
    """Mutable state of one continuous-batching run (pool + scheduler +
    compiled step functions + PRNG + counters)."""
    pool: object
    sched: object
    fns: Tuple
    key: object
    chunk: int
    max_nb: int
    b_prefill: int
    b_decode: int
    host_tier: int = 0            # host-tier capacity (0 = single level)
    prefetch_depth: int = 0       # max H2D stages dispatched per step
    hot: Optional[np.ndarray] = None   # (max_nb,) decayed selection counts
    host_ctr: Tuple = (0, 0, 0, 0)     # last pool tier counters seen
    t0: float = field(default_factory=time.perf_counter)
    steps: int = 0
    prefill_steps: int = 0
    decode_steps: int = 0
    occupancy: List[float] = field(default_factory=list)
    #: (rid, token) pairs emitted by the LAST step() — the streaming feed
    events: List[Tuple[int, int]] = field(default_factory=list)

    @property
    def now(self) -> float:
        return time.perf_counter() - self.t0


@dataclass
class ServeResult:
    """Outcome of one continuous-batching trace."""
    tokens: Dict[int, np.ndarray]        # rid -> (n_generated,) int32
    ttft_s: Dict[int, float]             # rid -> time to first token
    latency_s: Dict[int, float]          # rid -> arrival -> completion
    wall_s: float
    generated: int                       # total tokens across requests
    tokens_per_s: float                  # generated / wall_s
    steps: int = 0
    prefill_steps: int = 0
    decode_steps: int = 0
    occupancy: float = 0.0               # mean decode-batch fill [0, 1]
    method: str = ""
    backend: str = ""
    cached_len: Dict[int, int] = field(default_factory=dict)  # rid -> prefix hit
    prefix: Dict[str, float] = field(default_factory=dict)    # cache stats
    policy: str = ""                     # scheduling policy name
    preemptions: int = 0                 # suspends during this trace
    resumes: int = 0                     # re-admissions of suspended requests
    deadline_misses: int = 0             # TTFT deadlines blown this trace


class Engine:
    def __init__(self, model: Model, params, *, method: Optional[str] = None,
                 backend: Optional[str] = None,
                 sampler: SamplerConfig = SamplerConfig(),
                 mesh=None, registry=None):
        """``backend`` overrides the kernel backend for this engine
        ("xla" | "pallas_interpret" | "pallas"); None defers to the env /
        ``QuokaConfig.backend`` / hardware resolution (kernels/ops.py).

        ``mesh`` (jax.sharding.Mesh with axes from (pod, data, model), see
        launch/mesh.py) turns on tensor-/data-parallel serving: params are
        placed via ``sharding/specs.param_specs``, caches (one-shot AND the
        paged pool) via ``cache_specs``, the jitted step functions are
        donated + constrained with NamedSharding in/out specs, and QUOKA
        scoring routes through the T-local shard_map path when the KV-head
        axis under-shards the `model` axis (core/quoka.py).  Greedy outputs
        are token-identical to the meshless engine
        (tests/test_sharded_serving.py).

        ``registry`` (repro.obs.Registry) turns on serve-path telemetry:
        step spans, scheduler/pool counters, and the in-jit per-layer
        selection stats (the step functions compile WITH the LayerObs
        aux outputs — extra jit outputs, no host callbacks; with no
        registry they compile without them, so the metrics-off compute is
        bit-identical to pre-telemetry behavior).  ``Engine.stats`` is a
        view of this registry either way (an ephemeral one when off)."""
        from repro.kernels import ops as kops
        self.model = model
        self.mesh = mesh
        self.method = method or model.cfg.quoka.method
        self.backend = kops.resolve_backend(backend, model.cfg.quoka)
        # gather-free serve path: with QuokaConfig.fused_select_attn on and
        # a block-granular grid, every selecting layer inside the jitted
        # step functions routes through kernels/selected_attention.py
        # (core/plan.py::fused_route — the flag rides in via ctx["qcfg"],
        # no step-function change needed).  The paged gather that builds
        # the per-request cache VIEW remains (scatter-back needs it); what
        # the fused path removes is the per-layer full-budget materialize.
        # Benchmarks stamp this onto their records as the `fused` axis.
        self.fused = bool(getattr(model.cfg.quoka, "fused_select_attn",
                                  False))
        self.sampler = sampler
        self.registry = registry if registry is not None else obs_reg.NULL
        self._obs_on = bool(self.registry.enabled)
        self.stats: Dict[str, float] = {}   # prefix-cache stats of last serve
        self._warmed: set = set()           # generate() jit-warmup signatures
        donate = {}
        if mesh is not None:
            from repro.sharding import specs as sh
            self._param_sh = sh.to_shardings(
                mesh, sh.param_specs(model.cfg, params, mesh))
            params = jax.device_put(params, self._param_sh)
            # donate the cache so XLA updates the sharded buffers in place
            donate = dict(donate_argnums=(2,))
        self.params = params
        self._prefill = jax.jit(
            lambda p, batch, cache: model.prefill(p, batch, cache,
                                                  self.method,
                                                  backend=self.backend),
            **donate)
        self._decode = jax.jit(
            lambda p, tok, pos, cache: model.decode_step(p, tok, pos, cache,
                                                         self.method,
                                                         backend=self.backend),
            **(dict(donate_argnums=(3,)) if mesh is not None else {}))
        self._cont_fns: Dict = {}

    def _call(self, fn, *args):
        """Invoke a jitted step.  Under a mesh the sharding policy
        (sharding/ctx.py) and mesh context are active for the duration —
        they only matter at trace time (with_sharding_constraint + the
        quoka shard_map route), and save/restore keeps an outer launcher's
        policy intact."""
        if self.mesh is None:
            return fn(*args)
        from repro.sharding import ctx as shctx
        snap = shctx.get_policy()
        shctx.set_policy(self.mesh, tuple(a for a in ("pod", "data")
                                          if a in self.mesh.axis_names))
        try:
            with self.mesh:
                return fn(*args)
        finally:
            shctx.restore_policy(snap)

    # ------------------------------------------------------------------
    # one-shot batch mode
    # ------------------------------------------------------------------
    def pad_prompt(self, tokens: np.ndarray) -> Dict[str, np.ndarray]:
        """Left-pad to a chunk multiple.  Returns a batch dict whose
        ``pad`` entry carries the per-row pad count: inside the model, pad
        slots get ``pos = -1`` and are masked out of attention AND KV
        selection scoring — they are NOT ordinary context and cannot skew
        QUOKA's mean-query/key statistics.  (Recurrent blocks still consume
        pad embeddings sequentially; masking is exact for attention-cache
        architectures.)"""
        tokens = np.asarray(tokens)
        bcp = self.model.cfg.quoka.chunk_size
        t = tokens.shape[1]
        pad = (-t) % bcp
        if pad:
            tokens = np.concatenate(
                [np.zeros((tokens.shape[0], pad), tokens.dtype), tokens], 1)
        return {"tokens": tokens,
                "pad": np.full((tokens.shape[0],), pad, np.int32)}

    def generate(self, batch: Dict, max_new: int, *,
                 key=None) -> GenerationResult:
        """batch['tokens']: (b, T) prompt (T % chunk_size == 0; use
        pad_prompt, whose 'pad' entry rides along).  Extra modality inputs
        pass through."""
        model, params = self.model, self.params
        tokens = np.asarray(batch["tokens"])
        b, t = tokens.shape
        extra = t + (model.cfg.frontend.n_tokens
                     if model.cfg.family == "vlm" else 0)
        cap = extra + max_new
        g = model.cfg.quoka.granularity
        if self.method != "full" and g > 1:
            # block-granular plans need the cache view on the selection
            # grid (core/plan.py); padding slots read pos = -1 and their
            # blocks score NEG_INF, so rounding up is free
            cap = -(-cap // g) * g
        cache = model.init_cache(b, cap)
        if self.mesh is not None:
            from repro.sharding import specs as sh
            cache = jax.device_put(cache, sh.to_shardings(
                self.mesh, sh.cache_specs(model.cfg, cache, self.mesh)))
            batch = jax.device_put(batch, sh.to_shardings(
                self.mesh, sh.batch_spec(model.cfg, batch, self.mesh)))
        key = key if key is not None else jax.random.PRNGKey(0)

        # exclude jit compile time from the clocks: the first call on a new
        # (shapes, dtypes) signature traces + compiles inside the timed
        # region, so a cold first generate() used to report compile-dominated
        # ttft_s.  Warm the jit caches on a THROWAWAY cache with identical
        # avals (the real cache may be donated under a mesh), then time
        # execution only.  Repeat calls hit the signature set and skip this.
        sig = (b, t, cap, max_new > 1,
               tuple(sorted(k for k in batch if batch[k] is not None)))
        if sig not in self._warmed:
            wcache = model.init_cache(b, cap)
            if self.mesh is not None:
                from repro.sharding import specs as sh
                wcache = jax.device_put(wcache, sh.to_shardings(
                    self.mesh, sh.cache_specs(model.cfg, wcache, self.mesh)))
            wkey = jax.random.PRNGKey(0)
            wl, wcache = self._call(self._prefill, params, batch, wcache)
            wt = sample(wl, wkey, self.sampler)
            if max_new > 1:
                wl, wcache = self._call(self._decode, params, wt,
                                        jnp.asarray(extra), wcache)
                wt = sample(wl, wkey, self.sampler)
            wt.block_until_ready()
            del wcache
            self._warmed.add(sig)

        t0 = time.perf_counter()
        logits, cache = self._call(self._prefill, params, batch, cache)
        tok = sample(logits, key, self.sampler)
        tok.block_until_ready()
        ttft = time.perf_counter() - t0

        # device-side accumulation: one host transfer AFTER the loop.  A
        # per-step np.asarray(tok) forces a device->host sync per token and
        # poisons decode_tps with transfer latency.
        out = [tok]
        t1 = time.perf_counter()
        pos = extra
        for i in range(max_new - 1):
            key = jax.random.fold_in(key, i)
            logits, cache = self._call(self._decode, params, tok,
                                       jnp.asarray(pos), cache)
            tok = sample(logits, key, self.sampler)
            out.append(tok)
            pos += 1
        if max_new > 1:
            tok.block_until_ready()
        dt = time.perf_counter() - t1
        tps = (b * (max_new - 1)) / dt if max_new > 1 and dt > 0 else 0.0
        tokens_out = np.asarray(jnp.stack(out, axis=1))
        # true prompt length: ``t`` counts pad_prompt's LEFT padding, which
        # over-counted per-token TTFT normalisation for ragged prompts —
        # subtract the batch's pad entry (one pad per batch by construction)
        pad = batch.get("pad")
        prompt_len = t - (int(np.asarray(pad).reshape(-1)[0])
                          if pad is not None else 0)
        return GenerationResult(tokens=tokens_out, ttft_s=ttft,
                                decode_tps=tps, prompt_len=prompt_len,
                                method=self.method, backend=self.backend)

    # ------------------------------------------------------------------
    # continuous batching
    # ------------------------------------------------------------------
    def _continuous_fns(self, block_size: int, max_nb: int, b_prefill: int,
                        b_decode: int, num_blocks: int,
                        sel_on: bool = False):
        """Build (or fetch) the two jitted step functions for one static
        geometry: gather blocks -> model step -> sample -> scatter back.

        ``sel_on`` (host tier): the step fns additionally return the plans'
        per-logical-block selection counts ((rows, max_nb) int32, summed
        over layers — core/plan.py::pool_block_counts), the live signal the
        prefetch hook ranks host-tier staging by.  Same extra-jit-output
        pattern as obs; the sampled tokens are unaffected."""
        sig = (block_size, max_nb, b_prefill, b_decode, num_blocks, sel_on)
        if sig in self._cont_fns:
            return self._cont_fns[sig]
        from repro.serving import pool as pl
        model, method, backend = self.model, self.method, self.backend
        mesh = self.mesh
        chunk = model.cfg.quoka.chunk_size
        sampler = self.sampler
        # compiled-in telemetry: with a live registry the step fns return
        # the per-layer LayerObs pytree as an EXTRA jit output (device
        # scalars, fetched alongside the sampled tokens); without one they
        # compile exactly as before — bit-identical metrics-off compute
        obs_on = self._obs_on
        selb = (block_size, max_nb) if sel_on else None

        if mesh is not None:
            from repro.sharding import specs as sh

            def constrain(cache):
                # keep the gathered linear view on the canonical cache
                # layout (batch rows over FSDP axes, heads over model) —
                # without the constraint GSPMD can resolve the view to
                # replicated and gather/scatter stop being layout-local
                return sh.constrain_tree(
                    mesh, cache, sh.cache_specs(model.cfg, cache, mesh))
        else:
            def constrain(cache):
                return cache

        def prefill_step(p, data, table, tokens, start, vlen, key):
            cache = constrain(pl.gather(data, table, num_blocks, block_size))
            res = model.prefill_chunk(
                p, {"tokens": tokens}, start, cache, method,
                backend=backend, valid_len=vlen, with_obs=obs_on,
                sel_blocks=selb)
            last_h, cache = res[0], res[1]
            logits = model._readout(p, last_h[:, None, :])[:, 0]
            tok = sample(logits, key, sampler)
            wrote = jnp.where(vlen > 0, jnp.full_like(vlen, chunk), 0)
            touched = pl.touched_blocks(start, wrote, max_nb, block_size)
            data = pl.scatter(data, constrain(cache), table, touched,
                              num_blocks, block_size)
            return (data, tok) + tuple(res[2:])

        def decode_step(p, data, table, tokens, pos, live, key):
            cache = constrain(pl.gather(data, table, num_blocks, block_size))
            res = model.decode_step(p, tokens, pos, cache,
                                    method, backend=backend, with_obs=obs_on,
                                    sel_blocks=selb)
            logits, cache = res[0], res[1]
            tok = sample(logits, key, sampler)
            touched = pl.touched_blocks(pos, live, max_nb, block_size)
            data = pl.scatter(data, constrain(cache), table, touched,
                              num_blocks, block_size)
            return (data, tok) + tuple(res[2:])

        if mesh is None:
            fns = (jax.jit(prefill_step), jax.jit(decode_step))
        else:
            # donate + pin the pool pytree: the paged cache is by far the
            # largest resident buffer, and explicit in/out NamedShardings
            # keep its placement stable across steps instead of letting
            # propagation re-decide (and possibly reshard) per step fn
            from repro.sharding import specs as sh
            data_sh = sh.to_shardings(mesh, sh.cache_specs(
                model.cfg, self._pool_data_shapes(num_blocks, block_size),
                mesh, paged=True))
            rep = jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec())
            host = (rep,) * 4
            # `rep` broadcasts over the LayerObs pytree as an out-shardings
            # prefix: the per-layer stats are tiny replicated scalars
            out_sh = (data_sh, rep) + ((rep,) if obs_on else ()) \
                + ((rep,) if sel_on else ())
            fns = tuple(
                jax.jit(fn,
                        in_shardings=(self._param_sh, data_sh) + host + (rep,),
                        out_shardings=out_sh,
                        donate_argnums=(1,))
                for fn in (prefill_step, decode_step))
        self._cont_fns[sig] = fns
        return fns

    def _pool_data_shapes(self, num_blocks: int, block_size: int):
        """abstract pytree of the paged pool's device store (for specs)."""
        return jax.eval_shape(
            lambda: self.model.init_cache(num_blocks, block_size))

    def prefix_align(self) -> int:
        """Prefix-cache hit granularity: selection methods score per chunk,
        so hits must land on the B_CP grid to replay the exact computation;
        dense attention is chunking-invariant and shares at token
        granularity (COW partial tails)."""
        chunk = self.model.cfg.quoka.chunk_size
        return 1 if self.method == "full" else chunk

    def make_serve_state(self, requests: Sequence, *,
                         block_size: Optional[int] = None,
                         num_blocks: Optional[int] = None,
                         max_prefill_tokens: Optional[int] = None,
                         max_decode_batch: int = 8, key=None,
                         prefix_cache: bool = True,
                         host_tier_blocks: Optional[int] = None,
                         prefetch_depth: Optional[int] = None,
                         policy=None,
                         max_prefill_rows: Optional[int] = None) -> ServeState:
        """Size the pool/scheduler for a request trace and compile the two
        step functions (static geometry: chunk width, prefill rows, decode
        rows, blocks per request).

        ``host_tier_blocks`` > 0 turns on the hierarchical pool (demoted
        prefix blocks stay matchable on a host-memory tier; see
        serving/pool.py) and compiles the step functions with the
        selection-count prefetch oracle; ``prefetch_depth`` caps how many
        host blocks the per-step prefetch hook stages ahead of promotion.
        Both default from ``QuokaConfig``.

        ``policy`` (None | "fcfs" | "slo" | SchedPolicy) selects the
        scheduling policy (serving/policy.py); a preempting policy widens
        the per-request block geometry to the suspend/resume worst case.
        ``max_prefill_rows`` overrides the compiled prefill-row count
        (default: the full-chunk capacity ``max_prefill_tokens // chunk``);
        raise it to let short tail chunks — charged their real length —
        pack together."""
        from repro.serving.policy import resolve_policy
        from repro.serving.pool import PagedKVCache, max_blocks_bound
        from repro.serving.scheduler import Scheduler
        chunk = self.model.cfg.quoka.chunk_size
        block_size = block_size or chunk
        g = self.model.cfg.quoka.granularity
        if self.method != "full" and g > 1 and block_size % g != 0:
            raise ValueError(
                f"block_size={block_size} must be a multiple of the "
                f"selection granularity {g}: block-granular plans "
                f"materialize as whole-block sub-views of the paged pool "
                f"(serving/pool.py::gather_blocks), which needs the plan "
                f"grid to divide the pool grid")
        max_prefill_tokens = max_prefill_tokens or 4 * chunk
        pol = resolve_policy(policy)
        align = self.prefix_align() if prefix_cache else chunk
        max_nb = max(max_blocks_bound(r.prompt_len, r.max_new, chunk,
                                      block_size, align=align,
                                      preempt=pol.may_preempt)
                     for r in requests)
        if num_blocks is None:
            num_blocks = max_decode_batch * max_nb    # no contention
        b_p = (max(1, max_prefill_tokens // chunk)
               if max_prefill_rows is None else max(1, int(max_prefill_rows)))
        rows = b_p                  # scheduler cap (pre mesh-rounding)
        b_d = max_decode_batch
        if self.mesh is not None:
            # the pool's block axis shards over the FSDP axes — round the
            # pool and the step-ROW geometries up to the data-parallel
            # degree so every placement divides evenly instead of
            # replicating.  The scheduler's admission bound stays the
            # user's max_decode_batch; only the compiled decode batch
            # carries (idle) padding rows.
            from repro.sharding.specs import _axes_size, fsdp_axes
            dp = _axes_size(self.mesh, fsdp_axes(self.mesh))
            num_blocks = -(-num_blocks // dp) * dp
            b_p = -(-b_p // dp) * dp
            b_d = -(-b_d // dp) * dp
        qcfg = self.model.cfg.quoka
        htb = (int(getattr(qcfg, "host_tier_blocks", 0))
               if host_tier_blocks is None else int(host_tier_blocks))
        pfd = (int(getattr(qcfg, "prefetch_depth", 4))
               if prefetch_depth is None else int(prefetch_depth))
        pool = PagedKVCache(self.model, num_blocks, block_size,
                            mesh=self.mesh, host_tier_blocks=htb)
        # selection methods consume prefill in ``granularity``-sized score
        # units — that is the finest grid a packed chunk can be charged at
        grid = 1 if self.method == "full" else max(1, g)
        sched = Scheduler(pool, chunk, max_prefill_tokens, max_decode_batch,
                          prefix_cache=prefix_cache, prefix_align=align,
                          registry=self.registry, policy=pol,
                          max_prefill_rows=rows, token_grid=grid)
        fns = self._continuous_fns(block_size, max_nb, b_p, b_d, num_blocks,
                                   sel_on=htb > 0)
        key = key if key is not None else jax.random.PRNGKey(0)
        return ServeState(pool=pool, sched=sched, fns=fns, key=key,
                          chunk=chunk, max_nb=max_nb, b_prefill=b_p,
                          b_decode=b_d, host_tier=htb, prefetch_depth=pfd,
                          hot=np.zeros((max_nb,), np.float64))

    def _record_layer_obs(self, phase: str, lobs) -> None:
        """Feed one step's in-jit ``LayerObs`` pytree (per-layer device
        scalars, core/plan.py) into the registry: per-layer selected-KV
        fraction vs the budget ratio, plan refresh/reuse counts, and the
        score-distribution sketch.  NaN marks not-applicable (non-selecting
        blocks; budget/sketch on dense-fallback layers; sketch on plan-reuse
        steps) and is skipped.  One stacked host transfer per step."""
        reg = self.registry
        sel, ctx, bud, ref, lo, mean, hi = np.asarray(
            jnp.stack(lobs))                           # (7, n_layers)
        with np.errstate(invalid="ignore", divide="ignore"):
            frac = sel / ctx
            budf = bud / ctx
        for li in range(sel.shape[0]):
            if not np.isfinite(frac[li]):
                continue
            reg.set(f"select/layer{li:02d}/kv_fraction", frac[li])
            reg.observe("select/kv_fraction", frac[li])
            reg.observe(f"select/{phase}/kv_fraction", frac[li])
            if np.isfinite(budf[li]):
                reg.set(f"select/layer{li:02d}/budget_fraction", budf[li])
        fin = ref[np.isfinite(ref)]
        if fin.size:
            n_ref = float(fin.sum())
            reg.count("select/plan_refresh", n_ref)
            reg.count("select/plan_reuse", float(fin.size) - n_ref)
        for nm, v in (("score_lo", lo), ("score_mean", mean),
                      ("score_hi", hi)):
            v = v[np.isfinite(v)]
            if v.size:
                reg.observe(f"select/{nm}", float(v.mean()))

    def _note_hot(self, state: ServeState, sel, rows: int) -> None:
        """Fold one step's selection counts ((rows_compiled, max_nb) int32,
        the extra jit output) into the decayed per-logical-block hotness
        vector the prefetch hook ranks by.  Exponential decay keeps the
        ranking tracking the CURRENT working set's selection pattern."""
        counts = np.asarray(sel)[:rows].astype(np.float64).sum(axis=0)
        state.hot = 0.5 * state.hot + counts

    def _prefetch(self, state: ServeState) -> None:
        """Stage upcoming promotions' H2D copies while the step dispatched
        just above is still computing (double buffering: the copy for step
        N+1 overlaps step N's compute — the ``pool/h2d_stage`` span nests
        inside the step span, which is the trace-level proof of overlap).

        The oracle: the next waiting request's host-tier matches, ranked by
        the decayed QUOKA selection-count hotness of their LOGICAL block
        offsets (blocks whose positions the scoring pass keeps selecting
        get their bytes moved first), capped at ``prefetch_depth``.  Purely
        an ordering hint — promotion in ``alloc_prefix`` falls back to a
        synchronous-dispatch ``device_put`` for anything unstaged."""
        pool, sched = state.pool, state.sched
        if pool.host is None or state.prefetch_depth <= 0 \
                or not sched.waiting:
            return
        r = sched.waiting[0]
        fulls, tail = pool.match_prefix(r.tokens,
                                        chain=sched._chain.get(r.rid))
        cand = [(li, e[1]) for li, e in enumerate(fulls)
                if isinstance(e, tuple)]
        if tail is not None and isinstance(tail[0], tuple):
            cand.append((len(fulls), tail[0][1]))
        if not cand:
            return
        hot = state.hot
        cand.sort(key=lambda c: -(hot[c[0]] if c[0] < hot.shape[0]
                                  else 0.0))
        cand = cand[:state.prefetch_depth]
        with self.registry.span("pool/h2d_stage", blocks=len(cand)):
            n = sum(pool.stage(slot) for _, slot in cand)
        if n:
            self.registry.count("pool/staged", float(n))

    def _host_counters(self, state: ServeState) -> None:
        """Registry counters for the tier traffic of this step (deltas of
        the pool's monotonic totals)."""
        pool = state.pool
        cur = (pool.demoted, pool.promoted, pool.host_evictions,
               pool.staged_used)
        for name, now_v, prev in zip(
                ("pool/demoted", "pool/promoted", "pool/host_evictions",
                 "pool/staged_used"), cur, state.host_ctr):
            if now_v > prev:
                self.registry.count(name, float(now_v - prev))
        state.host_ctr = cur

    def step(self, state: ServeState) -> Tuple[int, int]:
        """One engine step: admit, run a mixed chunk-prefill step over up to
        ``max_prefill_tokens`` of pending prompt chunks, then a batched
        decode step over every active decode request.  Returns
        (prefill rows, decode rows) executed.  ``state.events`` is reset and
        filled with this step's emitted (rid, token) pairs — the feed
        ``serve_stream`` yields from."""
        pool, sched = state.pool, state.sched
        reg, obs = self.registry, self._obs_on
        state.events = []
        admitted = sched.admit(state.now)
        if obs:
            now = state.now
            for r in admitted:
                reg.observe("sched/admission_wait_s",
                            max(0.0, now - r.arrival_s))
            reg.set("sched/queue_depth", float(len(sched.waiting)))
            reg.set("sched/suspended", float(len(sched.suspended)))
            reg.set("sched/active", float(sched.n_active))
            reg.set("pool/occupancy", 1.0 - pool.num_free / pool.num_blocks)
            reg.set("pool/cached_blocks", float(pool.num_cached))
            if pool.host is not None:
                reg.set("pool/host_blocks", float(len(pool.host)))
        if pool.host is not None:
            self._host_counters(state)
        sel_at = 2 + (1 if obs else 0)     # extra-output slot (host tier)

        rows = sched.pack_prefill(state.now)
        if rows:
            tokens = np.zeros((state.b_prefill, state.chunk), np.int32)
            start = np.zeros((state.b_prefill,), np.int32)
            vlen = np.zeros((state.b_prefill,), np.int32)
            for i, (r, ch, st, vl) in enumerate(rows):
                tokens[i], start[i], vlen[i] = ch, st, vl
            table = pool.table_array([r.rid for r, *_ in rows],
                                     state.b_prefill, state.max_nb)
            state.key, k1 = jax.random.split(state.key)
            # the span brackets dispatch THROUGH the token fetch: with the
            # async runtime the np.asarray sync is where device time lands
            with reg.span("engine/prefill_step", rows=len(rows)):
                out = self._call(state.fns[0], self.params, pool.data,
                                 table, tokens, start, vlen, k1)
                pool.data, tok = out[0], out[1]
                # prefetch hook: dispatch next-step H2D stages BETWEEN the
                # step dispatch and the blocking token fetch, so the copies
                # run under the compute this step already queued
                self._prefetch(state)
                tok_np = np.asarray(tok)
            if obs:
                self._record_layer_obs("prefill", out[2])
                reg.count("engine/prefill_tokens", float(vlen.sum()))
            if state.host_tier:
                self._note_hot(state, out[sel_at], len(rows))
            now = state.now
            for i, (r, ch, st, vl) in enumerate(rows):
                ev = sched.note_prefilled(r, vl, int(tok_np[i]), now)
                if ev is not None:
                    state.events.append((r.rid, ev))
            state.prefill_steps += 1

        drows = sched.pack_decode()
        if drows:
            tokens = np.zeros((state.b_decode,), np.int32)
            pos = np.zeros((state.b_decode,), np.int32)
            live = np.zeros((state.b_decode,), np.int32)
            for i, r in enumerate(drows):
                tokens[i], pos[i], live[i] = r.out[-1], r.decode_pos, 1
            table = pool.table_array([r.rid for r in drows],
                                     state.b_decode, state.max_nb)
            state.key, k2 = jax.random.split(state.key)
            with reg.span("engine/decode_step", rows=len(drows)):
                out = self._call(state.fns[1], self.params, pool.data,
                                 table, tokens, pos, live, k2)
                pool.data, tok = out[0], out[1]
                self._prefetch(state)
                tok_np = np.asarray(tok)
            if obs:
                self._record_layer_obs("decode", out[2])
                reg.count("engine/decode_tokens", float(len(drows)))
            if state.host_tier:
                self._note_hot(state, out[sel_at], len(drows))
            now = state.now
            for i, r in enumerate(drows):
                state.events.append((r.rid,
                                     sched.note_decoded(r, int(tok_np[i]),
                                                        now)))
            # occupancy over the SCHEDULER's slot bound (the compiled row
            # batch may carry mesh-rounding padding rows)
            state.occupancy.append(len(drows) / sched.max_decode_batch)
            state.decode_steps += 1

        state.steps += 1
        return len(rows), len(drows)

    def serve_stream(self, requests: Sequence, *,
                     block_size: Optional[int] = None,
                     num_blocks: Optional[int] = None,
                     max_prefill_tokens: Optional[int] = None,
                     max_decode_batch: Optional[int] = None, key=None,
                     prefix_cache: Optional[bool] = None,
                     host_tier_blocks: Optional[int] = None,
                     prefetch_depth: Optional[int] = None,
                     policy=None, max_prefill_rows: Optional[int] = None,
                     state: Optional[ServeState] = None):
        """Streaming front-end of ``serve``: a generator yielding
        ``(rid, token)`` the step each token is emitted (the first token of
        a request right after its prefill completes, then one per decode
        step).  The generator's return value is the full ``ServeResult`` —
        ``serve()`` is exactly a drain of this stream.

        The idle wait is wakeup-correct for streaming consumers: the sleep
        until the next arrival is recomputed from the CURRENT clock every
        time the loop re-enters (a consumer may hold the generator between
        yields for arbitrarily long), and is capped at 0.25 s so a request
        arriving while the consumer processes tokens is admitted promptly
        rather than after a stale full-length sleep."""
        requests = list(requests)
        if not requests:
            return ServeResult({}, {}, {}, 0.0, 0, 0.0,
                               method=self.method, backend=self.backend)
        if state is None:
            state = self.make_serve_state(
                requests, block_size=block_size, num_blocks=num_blocks,
                max_prefill_tokens=max_prefill_tokens,
                max_decode_batch=(8 if max_decode_batch is None
                                  else max_decode_batch), key=key,
                prefix_cache=(True if prefix_cache is None
                              else prefix_cache),
                host_tier_blocks=host_tier_blocks,
                prefetch_depth=prefetch_depth, policy=policy,
                max_prefill_rows=max_prefill_rows)
        elif (block_size is not None or num_blocks is not None
              or max_prefill_tokens is not None or key is not None
              or max_decode_batch is not None or prefix_cache is not None
              or host_tier_blocks is not None or prefetch_depth is not None
              or policy is not None or max_prefill_rows is not None):
            # silently ignoring these would e.g. report cache-on numbers
            # for a prefix_cache=False A/B pass over a warm state
            raise ValueError(
                "serve(state=...) reuses the state's compiled geometry, "
                "cache configuration and policy; pass these options to "
                "make_serve_state instead")
        sched = state.sched
        if sched.pending():
            raise RuntimeError("serve state is mid-trace; drain it first")
        from repro.serving.pool import max_blocks_bound
        need = max(max_blocks_bound(r.prompt_len, r.max_new, state.chunk,
                                    state.pool.block_size,
                                    align=sched.prefix_align,
                                    preempt=sched.policy.may_preempt)
                   for r in requests)
        if need > state.max_nb:
            raise ValueError(
                f"trace needs {need} blocks/request > compiled geometry "
                f"{state.max_nb}; build a fresh state")
        live = {r.rid for r in requests}
        if len(live) != len(requests):
            raise ValueError("duplicate request ids in one trace")
        sched.done = []                     # per-trace completion list
        state.steps = state.prefill_steps = state.decode_steps = 0
        state.occupancy = []
        state.events = []
        pool = state.pool
        prefix0 = (pool.lookups, pool.hit_requests, pool.hit_tokens,
                   pool.prompt_tokens, pool.evictions, pool.cow_copies,
                   pool.demoted, pool.promoted, pool.host_evictions,
                   pool.staged_used)
        sched0 = (sched.preemptions, sched.resumes, sched.deadline_misses)
        pending = sorted(requests, key=lambda r: r.arrival_s)
        state.t0 = time.perf_counter()
        while pending or sched.pending():
            now = state.now
            while pending and pending[0].arrival_s <= now:
                sched.add(pending.pop(0))
            if not sched.pending():
                # idle: sleep until the next arrival instead of re-checking
                # the queue every 1 ms (a multi-second arrival gap used to
                # busy-spin ~1000 wakeups/s); the 0.25 s cap bounds clock
                # drift and keeps shutdown/interrupt latency sane.  Step
                # counts are untouched — only wakeups that packed nothing
                # are skipped (tests/test_scheduler.py asserts both).
                time.sleep(min(0.25, max(0.0, pending[0].arrival_s - now)))
                continue
            n_pf, n_dec = self.step(state)
            if n_pf == 0 and n_dec == 0 and sched.pending():
                raise RuntimeError(
                    "scheduler stall: pending requests but nothing packed")
            for ev in state.events:
                yield ev

        wall = state.now
        pool.check_invariants()
        assert pool.num_free + pool.num_evictable == pool.num_blocks, \
            "blocks leaked after drain"
        done = sched.done
        generated = sum(len(r.out) for r in done)
        hit_tok = pool.hit_tokens - prefix0[2]
        all_tok = pool.prompt_tokens - prefix0[3]
        # ``Engine.stats`` / ``ServeResult.prefix`` are REGISTRY VIEWS: the
        # per-serve prefix-cache stats land in gauges under serve/prefix/
        # (gauges, not counters — counters would accumulate across serve()
        # calls on one engine, while these are deltas of THIS trace) and are
        # read back as a flat suffix-keyed dict.  With metrics off an
        # ephemeral registry keeps the public dict shape identical.
        preg = self.registry if self._obs_on else obs_reg.Registry()
        sc = preg.scope("serve/prefix")
        sc.set("requests", pool.lookups - prefix0[0])
        sc.set("cache_hits", pool.hit_requests - prefix0[1])
        sc.set("hit_tokens", hit_tok)
        sc.set("prompt_tokens", all_tok)
        sc.set("hit_rate", hit_tok / all_tok if all_tok else 0.0)
        sc.set("evictions", pool.evictions - prefix0[4])
        sc.set("cow_copies", pool.cow_copies - prefix0[5])
        sc.set("cached_blocks", pool.num_cached)
        if pool.host is not None:
            sc.set("demoted", pool.demoted - prefix0[6])
            sc.set("promoted", pool.promoted - prefix0[7])
            sc.set("host_evictions", pool.host_evictions - prefix0[8])
            sc.set("staged_used", pool.staged_used - prefix0[9])
            sc.set("host_blocks", len(pool.host))
        self.stats = preg.view("serve/prefix")
        if self._obs_on:
            reg = self.registry
            for r in done:
                if r.ttft_s is not None:
                    reg.observe("serve/ttft_s", r.ttft_s)
                    reg.observe(f"tenant/{r.tenant}/ttft_s", r.ttft_s)
                dec = len(r.out) - 1
                if dec > 0 and r.done_s is not None and r.ttft_s is not None:
                    tpot = (r.done_s - r.arrival_s - r.ttft_s) / dec
                    reg.observe("serve/tpot_s", tpot)
                    reg.observe(f"tenant/{r.tenant}/tpot_s", tpot)
            reg.count("serve/requests_finished", float(len(done)))
            reg.count("serve/tokens_generated", float(generated))
            reg.event("serve_done", wall_s=wall, requests=len(done),
                      generated=generated,
                      tokens_per_s=generated / wall if wall > 0 else 0.0,
                      steps=state.steps,
                      prefill_steps=state.prefill_steps,
                      decode_steps=state.decode_steps,
                      method=self.method, backend=self.backend,
                      **{f"prefix_{k}": v for k, v in self.stats.items()})
        return ServeResult(
            tokens={r.rid: np.asarray(r.out, np.int32) for r in done},
            ttft_s={r.rid: r.ttft_s for r in done},
            latency_s={r.rid: r.done_s - r.arrival_s for r in done},
            wall_s=wall, generated=generated,
            tokens_per_s=generated / wall if wall > 0 else 0.0,
            steps=state.steps, prefill_steps=state.prefill_steps,
            decode_steps=state.decode_steps,
            occupancy=(float(np.mean(state.occupancy))
                       if state.occupancy else 0.0),
            method=self.method, backend=self.backend,
            cached_len={r.rid: r.cached_len for r in done},
            prefix=dict(self.stats),
            policy=sched.policy.name,
            preemptions=sched.preemptions - sched0[0],
            resumes=sched.resumes - sched0[1],
            deadline_misses=sched.deadline_misses - sched0[2])

    def serve(self, requests: Sequence, *, block_size: Optional[int] = None,
              num_blocks: Optional[int] = None,
              max_prefill_tokens: Optional[int] = None,
              max_decode_batch: Optional[int] = None, key=None,
              prefix_cache: Optional[bool] = None,
              host_tier_blocks: Optional[int] = None,
              prefetch_depth: Optional[int] = None,
              policy=None, max_prefill_rows: Optional[int] = None,
              state: Optional[ServeState] = None) -> ServeResult:
        """Serve a request trace with continuous batching.

        ``requests``: serving.request.Request objects (arrival_s offsets
        are honoured against the wall clock).  Each engine step packs up to
        ``max_prefill_tokens`` of pending prompt chunks plus every active
        decode token; admission ordering, prefill-packing order and
        preemption are delegated to ``policy`` (serving/policy.py — FCFS
        head-of-line by default, "slo" for EDF + weighted fairness +
        decode preemption) against pool capacity and the
        ``max_decode_batch`` batch-slot bound.  Greedy outputs under the
        default policy are token-identical to per-request ``generate``
        (tests/test_scheduler), including requests admitted via a
        prefix-cache hit (tests/test_prefix_cache).

        ``prefix_cache`` (default on) shares identical prompt prefixes
        across requests through the paged pool (multi-turn chats / shared
        system prompts skip re-prefilling cached blocks).  Pass a ``state``
        from ``make_serve_state`` to serve several traces over one warm
        pool — cached blocks of earlier traces stay matchable — as long as
        the new requests fit the compiled geometry.

        This is a drain of ``serve_stream``; use that directly to consume
        ``(rid, token)`` pairs as they are emitted."""
        stream = self.serve_stream(
            requests, block_size=block_size, num_blocks=num_blocks,
            max_prefill_tokens=max_prefill_tokens,
            max_decode_batch=max_decode_batch, key=key,
            prefix_cache=prefix_cache, host_tier_blocks=host_tier_blocks,
            prefetch_depth=prefetch_depth, policy=policy,
            max_prefill_rows=max_prefill_rows, state=state)
        while True:
            try:
                next(stream)
            except StopIteration as stop:
                return stop.value
