"""Paged KV pool for continuous batching, with cross-request prefix caching.

The device-side store is literally ``model.init_cache(num_blocks,
block_size)``: the cache's BATCH axis becomes the physical-block axis and
its capacity axis the within-block slot axis.  Every leaf therefore keeps
the ``pos``-derived mask semantics of serving/cache.py (``pos == -1`` marks
an empty/invalid slot), so full, QUOKA-selected and baseline-selected
attention over gathered views all share the one position-mask code path.

A request's logical cache is the concatenation of its blocks in
block-table order, materialised per step by ``gather`` (block-table indexed
``jnp.take`` with out-of-range fill: table id -1 reads as an empty block)
and written back by ``scatter`` (table id -1 / untouched blocks drop).
Host-side bookkeeping (free-list, per-request tables) lives on
``PagedKVCache``; the gather/scatter functions are pure and live inside the
engine's jitted step functions.

Prefix caching (multi-turn chats, shared system prompts):

  * FULL blocks of prompt KV are content-addressed by a rolling hash chain
    over their token ids (``h_i = hash(h_{i-1}, tokens_of_block_i)``, so a
    block's identity covers its whole prefix, not just its own tokens).
  * Blocks are REFCOUNTED: a cache-hit request pins a donor's prefix blocks
    into its own table read-only (the engine's scatter only ever writes
    blocks at/after the request's own prefill offset, so shared blocks are
    never written through a sharer's table).
  * When a block's refcount drops to zero it is not recycled immediately:
    registered (content-addressed) blocks move to an LRU list and stay
    resident — still matchable — until memory pressure evicts them into a
    fresh allocation.  Unregistered blocks are pos=-1-stamped and returned
    to the plain free list, so a recycled block can never leak a previous
    request's KV into a new allocation (stale ``pos`` values from a donor
    that sat at a *different* logical offset would otherwise look valid to
    the position masks).
  * Partially filled tail blocks (prompt_len % block_size != 0) are also
    registered, keyed by the hash of the full-block prefix they extend; a
    new request sharing the tail gets a COPY-ON-WRITE clone — the donor's
    block is copied into a privately owned block and the slots past the
    shared length are pos=-1-stamped — because the sharer must immediately
    write its own suffix into that block.

Hierarchical pool (``host_tier_blocks > 0``): a host-memory tier sits
behind the device pool, Double Sparsity-style.  Pressure-eviction DEMOTES
a registered block — content + registration move to a pinned host buffer
(``jax.device_put`` onto the ``pinned_host`` memory kind where the backend
has one; on CPU device memory already is host memory) instead of being
pos=-1-stamped away, so eviction becomes tiering rather than cache loss.
``match_prefix`` walks the hash chain across BOTH tiers; a host match is
PROMOTED at allocation time — an async H2D ``jax.device_put`` plus a
jitted block write into a fresh device block, re-registered on device so
the next sharer hits HBM directly.  ``stage`` lets the engine dispatch the
H2D copy for an upcoming promotion ahead of time (double buffering: the
copy for step N+1 overlaps step N's compute); staged buffers are consumed
by the promotion that needed them.  A hash is resident in exactly one
tier at a time (demotion moves it out, promotion/registration moves it
back), so matching never double-counts content.

Supported cache kinds: linear attention KV ("attn", "attn_moe", enc-free
GQA) and MLA latent caches.  Recurrent states (mamba/rwkv) do not
block-decompose over time, whisper cross-KV is encoder-owned, and
sliding-window ring buffers wrap at the window rather than the block — all
three are rejected at pool construction.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_UNSUPPORTED_KINDS = ("mamba", "mamba_shared_attn", "rwkv", "dec_cross",
                      "attn_local")

# chain-hash seed for the empty prefix (any fixed int; tuples of ints hash
# deterministically, unaffected by PYTHONHASHSEED)
_HASH_SEED = 0x51554F4B


def blocks_for_request(prompt_len: int, max_new: int, chunk_size: int,
                       block_size: int, cached_len: int = 0) -> int:
    """Blocks reserved at admission (conservative: no mid-flight OOM).

    Prefill writes whole B_CP chunks (the ragged tail is right-padded with
    pos = -1 garbage that decode later overwrites), so the reservation
    covers max(chunk-padded prefill span, prompt + max_new) slots.  With a
    prefix-cache hit the prefill chunks start at ``cached_len``, so the
    chunk grid — and its padded span — shifts with the hit."""
    span = cached_len + -(-(prompt_len - cached_len) // chunk_size) * chunk_size
    span = max(span, prompt_len + max_new)
    return -(-span // block_size)


def blocks_for_resume(kv_len: int, prompt_len: int, max_new: int,
                      chunk_size: int, block_size: int,
                      cached_len: int) -> int:
    """Blocks reserved when re-admitting a SUSPENDED request: the replay
    chunks (if any KV was evicted between suspend and resume) span from
    ``cached_len`` to ``kv_len`` on the chunk grid, and the table must
    still cover the request's full prompt + max_new token span."""
    span = kv_len if cached_len >= kv_len else \
        cached_len + -(-(kv_len - cached_len) // chunk_size) * chunk_size
    span = max(span, prompt_len + max_new)
    return -(-span // block_size)


def max_blocks_bound(prompt_len: int, max_new: int, chunk_size: int,
                     block_size: int, align: int = 0,
                     preempt: bool = False) -> int:
    """Upper bound of ``blocks_for_request`` over every admissible
    ``cached_len`` (static jit geometry must cover the worst case).

    ``align`` is the prefix-hit granularity: when it is a multiple of the
    chunk size the chunk grid never shifts and the cold bound holds; token
    granularity (align=1, dense attention) can shift the last chunk to
    start at prompt_len - 1.

    ``preempt``: the policy may suspend/resume this request mid-decode, so
    the bound must also cover the worst ``blocks_for_resume`` — a resume
    with the KV grown to ``prompt_len + max_new - 1`` tokens and the least
    favourable surviving-cache offset."""
    worst = 0 if (align and align % chunk_size == 0) \
        else max(0, prompt_len - 1)
    bound = max(blocks_for_request(prompt_len, max_new, chunk_size,
                                   block_size),
                blocks_for_request(prompt_len, max_new, chunk_size,
                                   block_size, cached_len=worst))
    if preempt:
        kv = prompt_len + max(0, max_new - 1)
        worst_r = 0 if (align and align % chunk_size == 0) \
            else max(0, kv - 1)
        bound = max(bound,
                    blocks_for_resume(kv, prompt_len, max_new, chunk_size,
                                      block_size, 0),
                    blocks_for_resume(kv, prompt_len, max_new, chunk_size,
                                      block_size, worst_r))
    return bound


def _chain_hashes(tokens: np.ndarray, block_size: int) -> List[int]:
    """Rolling hash per FULL block: identity covers the whole prefix."""
    h, out = _HASH_SEED, []
    for i in range(len(tokens) // block_size):
        h = hash((h, tuple(map(int, tokens[i * block_size:
                                           (i + 1) * block_size]))))
        out.append(h)
    return out


def _host_placement():
    """Placement fn for demoted block slabs: pinned host memory where the
    backend exposes the ``pinned_host`` memory kind (H2D from pinned pages
    is what lets ``jax.device_put`` overlap compute on GPU/TPU); on CPU the
    device memory already IS host memory, so slabs stay where they are; any
    other backend without the memory kind falls back to numpy."""
    try:
        mem = jax.devices()[0].memory("pinned_host")
        return lambda slab: jax.device_put(slab, mem)
    except Exception:
        if jax.default_backend() == "cpu":
            return lambda slab: slab
        return lambda slab: jax.tree.map(np.asarray, slab)


class HostTier:
    """Slot-addressed host-memory store of demoted block slabs + LRU.

    Pure storage: registration metadata and the cross-tier hash indices
    stay on ``PagedKVCache`` (mirroring the device tier's ``_reg``/
    ``_full``/``_tail``) so ``check_invariants`` covers both tiers in one
    place.  The pool drives eviction: ``oldest()`` names the victim, the
    pool unregisters it, then ``drop`` releases the slot."""

    def __init__(self, capacity: int):
        self.capacity = int(capacity)
        self._free: List[int] = list(range(self.capacity))
        self._slabs: Dict[int, object] = {}
        self._lru: "OrderedDict[int, None]" = OrderedDict()
        self._place = _host_placement()

    def __len__(self) -> int:
        return len(self._slabs)

    @property
    def num_free(self) -> int:
        return len(self._free)

    def oldest(self) -> int:
        return next(iter(self._lru))

    def put(self, slab) -> int:
        """Store one block slab (caller ensured a free slot)."""
        slot = self._free.pop()
        self._slabs[slot] = self._place(slab)
        self._lru[slot] = None                     # MRU end
        return slot

    def get(self, slot: int):
        return self._slabs[slot]

    def touch(self, slot: int) -> None:
        self._lru.move_to_end(slot)

    def drop(self, slot: int) -> None:
        del self._slabs[slot]
        del self._lru[slot]
        self._free.append(slot)


class PagedKVCache:
    """Fixed-size-block KV pool + per-request block tables + free-list +
    content-addressed prefix cache (refcounts, LRU eviction, COW tails)."""

    def __init__(self, model, num_blocks: int, block_size: int, mesh=None,
                 host_tier_blocks: int = 0):
        kinds = [k for s in model.stacks for k in s.period]
        bad = sorted(set(k for k in kinds if k in _UNSUPPORTED_KINDS))
        if bad:
            raise ValueError(
                f"paged KV pool supports attention/MLA caches only; "
                f"model has unsupported block kinds {bad}")
        if model.cfg.family == "vlm":
            raise ValueError("paged KV pool does not support VLM frontends")
        if host_tier_blocks and mesh is not None:
            raise ValueError(
                "host tier + mesh is not supported yet: demotion would "
                "have to gather a sharded block slab per eviction")
        self.model = model
        self.mesh = mesh
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        self.data = model.init_cache(self.num_blocks, self.block_size)
        if mesh is not None:
            # blocks batch-shard over the FSDP axes (pool memory scales
            # with the data-parallel degree), heads over `model`; the
            # within-block slot axis is never split (sharding/specs.py
            # ``paged=True``) — a block is the atomic placement unit
            from repro.sharding import specs as sh
            self.data = jax.device_put(self.data, sh.to_shardings(
                mesh, sh.cache_specs(model.cfg, self.data, mesh,
                                     paged=True)))
        self._free: List[int] = list(range(self.num_blocks))
        self._tables: Dict[int, List[int]] = {}
        # ---- prefix cache state ----
        self._ref: Dict[int, int] = {}              # block -> live refcount
        self._lru: "OrderedDict[int, None]" = OrderedDict()  # evictable
        self._reg: Dict[int, Tuple] = {}            # block -> registration
        self._full: Dict[int, int] = {}             # chain hash -> block
        self._tail: Dict[int, int] = {}             # prefix hash -> block
        # ---- host tier (hierarchical pool; see module docstring;
        # mesh-incompatibility guarded at the top of __init__) ----
        self.host: Optional[HostTier] = (HostTier(host_tier_blocks)
                                         if host_tier_blocks else None)
        self._h_reg: Dict[int, Tuple] = {}          # host slot -> registration
        self._h_full: Dict[int, int] = {}           # chain hash -> host slot
        self._h_tail: Dict[int, int] = {}           # prefix hash -> host slot
        self._staged: Dict[int, object] = {}        # host slot -> device slab
        # ---- counters (Engine.stats / ServeResult.prefix) ----
        self.evictions = 0
        self.cow_copies = 0
        self.lookups = 0
        self.hit_requests = 0
        self.hit_tokens = 0
        self.prompt_tokens = 0
        self.demoted = 0
        self.promoted = 0
        self.host_evictions = 0                     # host-tier cache LOSS
        self.staged_used = 0                        # promotions from staging
        # module-level jit singletons: the compiled-executable cache lives
        # on the WRAPPER, so per-instance jax.jit(...) here would recompile
        # for every pool (each warm/measure serve state builds its own)
        self._stamp_fn = _stamp_fn
        self._cow_fn = _cow_fn
        self._extract_fn = _extract_fn
        self._write_fn = _write_fn
        self._cow_slab_fn = _cow_slab_fn

    # ---- free-list bookkeeping ------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_evictable(self) -> int:
        return len(self._lru)

    @property
    def num_cached(self) -> int:
        """Registered (matchable) blocks, live or evictable."""
        return len(self._reg)

    @property
    def num_allocated(self) -> int:
        return self.num_blocks - len(self._free) - len(self._lru)

    def can_alloc(self, n: int, exclude: Sequence[int] = ()) -> bool:
        """Can ``n`` FRESH blocks be produced (free list + LRU eviction),
        without evicting any block in ``exclude``?"""
        lru = len(self._lru) - sum(1 for b in exclude if b in self._lru)
        return n <= len(self._free) + lru

    def alloc(self, rid: int, n: int) -> List[int]:
        return self.alloc_prefix(rid, n)

    def alloc_prefix(self, rid: int, n_total: int,
                     shared: Sequence = (),
                     cow: Optional[Tuple] = None) -> List[int]:
        """Build request ``rid``'s table: ``shared`` prefix entries in
        logical order followed by the remaining fresh blocks.  An entry is
        either a physical DEVICE block id (int, refcount-pinned read-only)
        or ``("host", slot)`` — a host-tier block, PROMOTED here: its slab
        is written into a fresh device block (the staged H2D buffer when
        the engine prefetched it, an async ``jax.device_put`` otherwise)
        and re-registered on device under its hash, so only device-shared
        entries come for free while promotions consume fresh blocks.

        ``cow = (src, keep)`` initialises the first post-prefix fresh block
        as a copy of ``src`` — a device block id or ``("host", slot)`` —
        with slots >= ``keep`` invalidated (shared partial tail).  A host
        COW source is COPIED, not consumed: the clone is private to the
        sharer, so the host copy stays matchable."""
        if rid in self._tables:
            raise RuntimeError(f"request {rid} already holds blocks")
        dev_shared = [e for e in shared if not isinstance(e, tuple)]
        promote = [e[1] for e in shared if isinstance(e, tuple)]
        cow_host = cow is not None and isinstance(cow[0], tuple)
        n_fresh = n_total - len(dev_shared)
        protect = dev_shared + ([cow[0]] if cow and not cow_host else [])
        if not self.can_alloc(n_fresh, exclude=protect):
            raise RuntimeError(
                f"pool exhausted: need {n_fresh} fresh blocks, "
                f"{len(self._free)} free + {len(self._lru)} evictable")
        # pin the shared prefix FIRST so fresh allocation cannot evict it
        for b in dev_shared:
            self._pin(b)
        # consume host sources BEFORE fresh allocation: taking fresh blocks
        # can itself demote device blocks into the host tier, and a host
        # eviction triggered by that must not race the slots this request
        # is about to promote
        promo = [self._take_host(s) for s in promote]   # [(slab, reg)]
        cow_slab = self._peek_host(cow[0][1]) if cow_host else None
        fresh, stale = [], []
        for _ in range(n_fresh):
            b, was_cached = self._take_fresh(protect)
            if was_cached:
                stale.append(b)
            fresh.append(b)
            self._ref[b] = 1
        self._stamp(stale)                 # evicted content is stale
        # build the table in logical order, promotions drawing fresh blocks
        it = iter(fresh)
        table, promo_dst = [], []
        for e in shared:
            if isinstance(e, tuple):
                promo_dst.append(next(it))
                table.append(promo_dst[-1])
            else:
                table.append(e)
        rest = list(it)
        for (slab, reg), dst in zip(promo, promo_dst):
            self.data = self._write_fn(self.data, slab,
                                       jnp.asarray(dst, jnp.int32))
            self._reg[dst] = reg           # re-registered on DEVICE
            index = self._full if reg[0] == "full" else self._tail
            index[reg[1]] = dst
            self.promoted += 1
        if cow is not None:
            src, keep = cow
            if cow_host:
                self.data = self._cow_slab_fn(
                    self.data, cow_slab, jnp.asarray(rest[0], jnp.int32),
                    jnp.asarray(keep, jnp.int32))
            else:
                if src not in self._ref and src not in self._lru:
                    raise RuntimeError(f"COW source block {src} not resident")
                self.data = self._cow_fn(
                    self.data, jnp.asarray(src, jnp.int32),
                    jnp.asarray(rest[0], jnp.int32),
                    jnp.asarray(keep, jnp.int32))
            self.cow_copies += 1
        self._tables[rid] = table + rest
        return self._tables[rid]

    def free(self, rid: int) -> List[int]:
        """Release a request's blocks.  Registered blocks stay resident on
        the LRU list (matchable until evicted); the rest are pos=-1-stamped
        so no stale KV can leak into a later allocation.  Returns the
        blocks that landed on the LRU list (the suspend path demotes
        exactly those)."""
        blocks = self._tables.pop(rid)   # KeyError on double free
        stale, retained = [], []
        for b in blocks:
            self._ref[b] -= 1
            if self._ref[b] == 0:
                del self._ref[b]
                if b in self._reg:
                    self._lru[b] = None          # MRU end, content kept
                    retained.append(b)
                else:
                    stale.append(b)
                    self._free.append(b)
        self._stamp(stale)
        return retained

    def table(self, rid: int) -> List[int]:
        return self._tables[rid]

    def table_array(self, rids: Sequence[int], rows: int,
                    max_blocks: int) -> np.ndarray:
        """(rows, max_blocks) int32 block table, -1 padded (empty block).
        Rows beyond len(rids) are idle (all -1)."""
        tab = np.full((rows, max_blocks), -1, np.int32)
        for i, rid in enumerate(rids):
            blocks = self._tables[rid]
            tab[i, :len(blocks)] = blocks
        return tab

    # ---- prefix cache ----------------------------------------------------
    def match_prefix(self, tokens: np.ndarray,
                     chain: Optional[List[int]] = None
                     ) -> Tuple[List, Optional[Tuple]]:
        """Longest cached prefix of ``tokens``: (matched full blocks, tail).
        Each full-block entry is a device block id (int) or — with the host
        tier on — ``("host", slot)`` for a demoted block (device wins when
        a hash could be in either tier; demotion keeps them disjoint).
        ``tail = (src, n_common)`` if a registered partial tail (device id
        or host entry, same encoding) extends the matched full-block prefix
        by ``n_common`` shared tokens.  ``chain`` is the precomputed
        ``_chain_hashes`` of ``tokens`` — the scheduler caches it so a
        pool-blocked request re-matched every engine step doesn't re-hash
        its whole prompt each time."""
        toks = np.asarray(tokens).reshape(-1)
        bs = self.block_size
        if chain is None:
            chain = _chain_hashes(toks, bs)
        h, fulls = _HASH_SEED, []
        for h2 in chain:
            b = self._full.get(h2)
            if b is None and self.host is not None:
                s = self._h_full.get(h2)
                if s is not None:
                    b = ("host", s)
                    self.host.touch(s)
            if b is None:
                break
            fulls.append(b)
            h = h2
        tail = None
        tb = self._tail.get(h)
        t_toks = self._reg[tb][2] if tb is not None else None
        if tb is None and self.host is not None:
            s = self._h_tail.get(h)
            if s is not None:
                tb = ("host", s)
                t_toks = self._h_reg[s][2]
                self.host.touch(s)
        if tb is not None:
            rem = toks[len(fulls) * bs:]
            m = 0
            while m < min(len(rem), len(t_toks)) and \
                    int(rem[m]) == t_toks[m]:
                m += 1
            if m > 0:
                tail = (tb, m)
        return fulls, tail

    def register_prefix(self, rid: int, tokens: np.ndarray,
                        chain: Optional[List[int]] = None) -> None:
        """Content-address request ``rid``'s prompt blocks (call once the
        prompt is fully prefilled: full blocks are final; the partial tail's
        prompt slots are final — later decode tokens land past them)."""
        toks = np.asarray(tokens).reshape(-1)
        bs = self.block_size
        table = self._tables[rid]
        if chain is None:
            chain = _chain_hashes(toks, bs)
        h = _HASH_SEED
        for i, h2 in enumerate(chain):
            h = h2
            b = table[i]
            if b in self._reg or h in self._full:
                continue                 # shared / duplicate content
            self._reg[b] = ("full", h)
            self._full[h] = b
        rem = len(toks) % bs
        if rem:
            tb = table[len(toks) // bs]
            if tb not in self._reg and h not in self._tail:
                self._reg[tb] = ("tail", h,
                                 tuple(map(int, toks[len(toks) - rem:])))
                self._tail[h] = tb
        if self.host is not None:
            # single-residency: a degraded (cold) admit can re-prefill and
            # register content whose demoted copy still sits on the host
            # tier — drop the host copy so a hash matches in exactly one
            # tier (the device copy is the one future sharers should pin)
            for idx, hmap in ((self._full, self._h_full),
                              (self._tail, self._h_tail)):
                for hh in [hh for hh in hmap if hh in idx]:
                    self._h_unregister(hmap[hh])

    def register_suspend(self, rid: int, tokens: np.ndarray) -> None:
        """Content-address request ``rid``'s blocks over ``tokens`` — the
        prompt PLUS the generated tokens whose KV the cache holds — before
        suspension releases them.  Unlike ``register_prefix`` this must
        UPGRADE stale registrations: decode may have grown a registered
        partial tail (same block, more valid tokens) or filled it into a
        full block (tail registration replaced by a full-chain one)."""
        toks = np.asarray(tokens).reshape(-1)
        bs = self.block_size
        table = self._tables[rid]
        chain = _chain_hashes(toks, bs)
        h = _HASH_SEED
        for i, h2 in enumerate(chain):
            h = h2
            b = table[i]
            reg = self._reg.get(b)
            if reg is not None and reg[0] == "tail":
                # decode filled this once-partial tail into a full block
                self._unregister(b)
                reg = None
            if reg is not None or h in self._full:
                continue                 # shared / duplicate content
            self._reg[b] = ("full", h)
            self._full[h] = b
        rem = len(toks) % bs
        if rem:
            tb = table[len(toks) // bs]
            t_toks = tuple(map(int, toks[len(toks) - rem:]))
            cur = self._reg.get(tb)
            if cur is not None and cur[0] == "tail" and cur[1] == h \
                    and self._tail.get(h) == tb:
                if len(cur[2]) < rem:    # decode extended the tail
                    self._reg[tb] = ("tail", h, t_toks)
            elif cur is None and h not in self._tail:
                self._reg[tb] = ("tail", h, t_toks)
                self._tail[h] = tb
        if self.host is not None:
            for idx, hmap in ((self._full, self._h_full),
                              (self._tail, self._h_tail)):
                for hh in [hh for hh in hmap if hh in idx]:
                    self._h_unregister(hmap[hh])

    def suspend(self, rid: int, tokens: np.ndarray) -> Tuple[int, int]:
        """Preemption: release request ``rid``'s blocks with their KV kept
        matchable for resume.  ``tokens`` is the prompt plus the generated
        tokens the cache holds KV for (``Request.kv_len`` of them).  The
        blocks are content-registered (``register_suspend``) and freed;
        with the host tier on, the exclusively-owned ones are demoted
        IMMEDIATELY — suspension's whole point is to free device blocks
        now, not at the next pressure eviction — while blocks shared with
        live requests stay pinned on device.  Without a host tier they
        park on the LRU list (resume re-pins them; pressure in between is
        real cache loss and forces a replay).  Returns (blocks released
        from this request's table, blocks demoted to host)."""
        self.register_suspend(rid, tokens)
        n_total = len(self._tables[rid])
        retained = self.free(rid)
        demoted = 0
        if self.host is not None:
            stale = []
            for b in retained:
                if b in self._lru and b in self._reg:
                    del self._lru[b]
                    self._demote(b)
                    stale.append(b)
                    self._free.append(b)
                    demoted += 1
            self._stamp(stale)
        return n_total, demoted

    # ---- internals -------------------------------------------------------
    def _pin(self, b: int) -> None:
        """Refcount++ a resident block (pulling it off the LRU list)."""
        if b not in self._ref:
            if b not in self._lru:
                raise RuntimeError(f"block {b} not resident, cannot share")
            del self._lru[b]
            self._ref[b] = 1
        else:
            self._ref[b] += 1

    def _take_fresh(self, protect: Sequence[int]) -> Tuple[int, bool]:
        """One fresh block: free list first, then LRU eviction.  With the
        host tier on the evicted block DEMOTES (content + registration move
        to a host slab, still matchable); otherwise it just loses its cache
        entry.  Returns (block, needs stamping) — free-list blocks were
        stamped when freed."""
        if self._free:
            return self._free.pop(), False
        for b in self._lru:                        # oldest first
            if b not in protect:
                del self._lru[b]
                if self.host is not None:
                    self._demote(b)
                else:
                    self._unregister(b)
                self.evictions += 1
                return b, True
        raise RuntimeError("pool exhausted: no evictable block")

    def _unregister(self, b: int) -> None:
        reg = self._reg.pop(b)
        index = self._full if reg[0] == "full" else self._tail
        if index.get(reg[1]) == b:
            del index[reg[1]]

    # ---- host tier internals --------------------------------------------
    def _demote(self, b: int) -> None:
        """Move an evicted registered block into the host tier: slice its
        slab out of the pool (a jitted read dispatched BEFORE the caller
        stamps/recycles the block — dataflow keeps it ordered), place it on
        pinned host memory, and move the hash registration across tiers.
        The host tier's own eviction (oldest slot) is real cache loss."""
        reg = self._reg.pop(b)
        index = self._full if reg[0] == "full" else self._tail
        if index.get(reg[1]) != b:
            return                          # duplicate content; nothing owned
        del index[reg[1]]
        hmap = self._h_full if reg[0] == "full" else self._h_tail
        old = hmap.get(reg[1])
        if old is not None:                 # stale host copy of the same hash
            self._h_unregister(old)
        if self.host.num_free == 0:
            self._h_unregister(self.host.oldest())
            self.host_evictions += 1
        slab = self._extract_fn(self.data, jnp.asarray(b, jnp.int32))
        slot = self.host.put(slab)
        self._h_reg[slot] = reg
        hmap[reg[1]] = slot
        self.demoted += 1

    def _h_unregister(self, slot: int) -> None:
        reg = self._h_reg.pop(slot)
        index = self._h_full if reg[0] == "full" else self._h_tail
        if index.get(reg[1]) == slot:
            del index[reg[1]]
        self.host.drop(slot)
        self._staged.pop(slot, None)

    def _take_host(self, slot: int) -> Tuple[object, Tuple]:
        """Consume host slot ``slot`` for promotion: returns (device slab,
        registration).  A staged buffer (``stage``) is used when present —
        its H2D copy was dispatched while an earlier step computed; the
        fallback ``jax.device_put`` still dispatches asynchronously, and
        the jitted write that scatters the slab into ``self.data`` orders
        after it by dataflow."""
        reg = self._h_reg.pop(slot)
        index = self._h_full if reg[0] == "full" else self._h_tail
        if index.get(reg[1]) == slot:
            del index[reg[1]]
        slab = self._staged.pop(slot, None)
        if slab is not None:
            self.staged_used += 1
        else:
            slab = jax.device_put(self.host.get(slot))
        self.host.drop(slot)
        return slab, reg

    def _peek_host(self, slot: int) -> object:
        """Device slab of host slot ``slot`` WITHOUT consuming it (COW tail
        sources: the sharer's clone is private, so the host copy stays
        matchable for the next sharer)."""
        self.host.touch(slot)
        slab = self._staged.get(slot)
        if slab is not None:
            self.staged_used += 1
            return slab
        return jax.device_put(self.host.get(slot))

    def stage(self, slot: int) -> bool:
        """Dispatch the H2D copy for host slot ``slot`` ahead of its
        promotion (the engine's prefetch hook calls this while the step it
        just dispatched is still computing — double buffering).  Idempotent;
        returns True when a new copy was started."""
        if self.host is None or slot in self._staged:
            return False
        self._staged[slot] = jax.device_put(self.host.get(slot))
        return True

    def _stamp(self, blocks: List[int]) -> None:
        """pos=-1-stamp ``blocks`` on device: recycled blocks must read as
        empty (a donor's stale positions would pass the validity masks).
        The id vector is padded to the next power of two (not the pool
        size) so per-free device work is O(freed blocks) while the jit
        cache stays bounded to log2(num_blocks) shape variants."""
        if not blocks:
            return
        n = 1
        while n < len(blocks):
            n *= 2
        ids = np.full((min(n, self.num_blocks),), self.num_blocks, np.int32)
        ids[:len(blocks)] = blocks                 # rest drop out of range
        self.data = self._stamp_fn(self.data, jnp.asarray(ids))

    def check_invariants(self) -> None:
        """No block leaked, double-allocated, double-freed, or in two of
        {allocated, free, LRU}; refcounts match table membership; the hash
        indices and registrations agree."""
        refs: Dict[int, int] = {}
        for t in self._tables.values():
            assert len(set(t)) == len(t), "block twice in one table"
            for b in t:
                refs[b] = refs.get(b, 0) + 1
        assert refs == self._ref, "refcounts out of sync with tables"
        held = set(refs)
        free, lru = set(self._free), set(self._lru)
        assert len(self._free) == len(free), "block double-freed"
        assert not (held & free), "allocated block on the free list"
        assert not (held & lru), "allocated block on the LRU list"
        assert not (free & lru), "block both free and evictable"
        assert sorted(held | free | lru) == list(range(self.num_blocks)), \
            "block leaked or invented"
        for h, b in self._full.items():
            assert self._reg.get(b, (None, None))[:2] == ("full", h)
        for h, b in self._tail.items():
            r = self._reg.get(b)
            assert r is not None and r[0] == "tail" and r[1] == h
        for b in self._reg:
            assert b in held or b in lru, "registered block recycled"
        if self.host is not None:
            slots = set(self._h_reg)
            assert slots == set(self.host._slabs) == set(self.host._lru), \
                "host registrations out of sync with stored slabs"
            assert len(slots) + self.host.num_free == self.host.capacity, \
                "host slot leaked or invented"
            assert not (slots & set(self.host._free)), \
                "host slot both stored and free"
            for h, s in self._h_full.items():
                assert self._h_reg.get(s, (None, None))[:2] == ("full", h)
            for h, s in self._h_tail.items():
                r = self._h_reg.get(s)
                assert r is not None and r[0] == "tail" and r[1] == h
            assert not (set(self._h_full) & set(self._full)), \
                "full-block hash resident in both tiers"
            assert not (set(self._h_tail) & set(self._tail)), \
                "tail hash resident in both tiers"
            assert set(self._staged) <= slots, "staged buffer for freed slot"


# ---------------------------------------------------------------------------
# pure device helpers (jitted once per pool, donated data)
# ---------------------------------------------------------------------------

def _stamp_blocks(data, ids):
    """Set pos = -1 across blocks ``ids`` (padded with out-of-range ids,
    which drop).  Only integer leaves carry positions; KV payloads are left
    in place — the position masks make them unreadable."""
    def s(leaf):
        if leaf.ndim < 3 or not jnp.issubdtype(leaf.dtype, jnp.integer):
            return leaf
        upd = jnp.full((leaf.shape[0], ids.shape[0]) + leaf.shape[2:],
                       -1, leaf.dtype)
        return leaf.at[:, ids].set(upd, mode="drop")

    return jax.tree.map(s, data)


def _cow_block(data, src, dst, keep):
    """Copy block ``src`` into ``dst`` (copy-on-write of a shared partial
    tail), invalidating slots >= ``keep``: those hold the donor's private
    suffix/decode KV, which the sharer must not see."""
    def c(leaf):
        if leaf.ndim < 3:
            return leaf
        row = jnp.take(leaf, src, axis=1)          # (R, block_size, ...)
        if jnp.issubdtype(leaf.dtype, jnp.integer):
            slot = jnp.arange(leaf.shape[2], dtype=jnp.int32)
            valid = (slot < keep).reshape((1, -1) + (1,) * (row.ndim - 2))
            row = jnp.where(valid, row, -1)
        return leaf.at[:, dst].set(row)

    return jax.tree.map(c, data)


def _extract_block(data, b):
    """Slice block ``b`` out of the pool as a standalone slab pytree
    (each KV leaf (R, block_size, ...)) — the D2H half of demotion."""
    def e(leaf):
        if leaf.ndim < 3:
            return leaf
        return jnp.take(leaf, b, axis=1)

    return jax.tree.map(e, data)


def _write_block(data, slab, dst):
    """Write an extracted slab into block ``dst`` — the H2D half of
    promotion.  The slab's buffers arrive via ``jax.device_put`` (possibly
    pre-staged); dataflow orders this write after that copy completes."""
    def w(leaf, s):
        if leaf.ndim < 3:
            return leaf
        return leaf.at[:, dst].set(s.astype(leaf.dtype))

    return jax.tree.map(w, data, slab)


def _cow_from_slab(data, slab, dst, keep):
    """``_cow_block`` with a host-tier source: copy an extracted slab into
    ``dst``, invalidating slots >= ``keep`` (shared partial tail)."""
    def c(leaf, s):
        if leaf.ndim < 3:
            return leaf
        row = s.astype(leaf.dtype)
        if jnp.issubdtype(leaf.dtype, jnp.integer):
            slot = jnp.arange(leaf.shape[2], dtype=jnp.int32)
            valid = (slot < keep).reshape((1, -1) + (1,) * (row.ndim - 2))
            row = jnp.where(valid, row, -1)
        return leaf.at[:, dst].set(row)

    return jax.tree.map(c, data, slab)


# shared jit singletons (see PagedKVCache.__init__): compiled executables
# are cached per wrapper, so one wrapper per process amortises compilation
# across every pool instance of the same geometry
_stamp_fn = jax.jit(_stamp_blocks, donate_argnums=0)
_cow_fn = jax.jit(_cow_block, donate_argnums=0)
_extract_fn = jax.jit(_extract_block)
_write_fn = jax.jit(_write_block, donate_argnums=0)
_cow_slab_fn = jax.jit(_cow_from_slab, donate_argnums=0)


# ---------------------------------------------------------------------------
# pure gather/scatter (used inside the engine's jitted step functions)
# ---------------------------------------------------------------------------

def gather(data, table, num_blocks: int, block_size: int):
    """Materialise per-request linear caches from the pool.

    table: (b, max_nb) int32 physical block ids, -1 = empty.  Returns a
    cache pytree whose KV leaves are (R, b, max_nb * block_size, ...) — a
    standard linear cache view; empty blocks read as pos = -1 / zeros, so
    the position-mask machinery needs no special case."""
    b, nb = table.shape
    idx = jnp.where(table < 0, num_blocks, table).reshape(-1)

    def g(leaf):
        if leaf.ndim < 3:
            return leaf                          # enc_done & friends
        fill = -1 if jnp.issubdtype(leaf.dtype, jnp.integer) else 0
        out = jnp.take(leaf, idx, axis=1, mode="fill", fill_value=fill)
        return out.reshape(leaf.shape[0], b, nb * block_size,
                           *leaf.shape[3:])

    with jax.named_scope("pool_gather"):
        return jax.tree.map(g, data)


def gather_blocks(data, table, block_ids, num_blocks: int, block_size: int):
    """Materialise only SELECTED blocks of each request: a sub-view of
    ``gather`` driven by per-request logical block indices (b, nb_sel)
    int32, -1 = padding.

    This is the paged backing of core/plan.py's block-granular
    materialize: a plan built on the pool grid (granularity divides
    block_size) names whole logical blocks, so re-indexing the block
    TABLE — not the tokens — keeps the physical gather whole-block
    contiguous (one dynamic slice of ``block_size`` rows per selected
    block, never a per-token gather).  Padding ids read as pos = -1 /
    zeros, same as ``gather``."""
    sub = jnp.take_along_axis(table, jnp.maximum(block_ids, 0), axis=1)
    sub = jnp.where(block_ids >= 0, sub, -1)
    return gather(data, sub, num_blocks, block_size)


def scatter(data, gathered, table, touched, num_blocks: int,
            block_size: int):
    """Write gathered views back into the pool.

    ``touched`` (b, max_nb) bool limits the write to blocks the step
    actually modified; untouched and null (-1) table entries are mapped out
    of range and dropped.  Prefix-shared blocks are safe behind this mask:
    a sharer's writes start at its own prefill offset, so its touched
    window never covers the shared prefix."""
    b, nb = table.shape
    idx = jnp.where((table >= 0) & touched, table, num_blocks).reshape(-1)

    def s(pool_leaf, gath_leaf):
        if pool_leaf.ndim < 3:
            return pool_leaf
        blocks = gath_leaf.reshape(gath_leaf.shape[0], b * nb, block_size,
                                   *gath_leaf.shape[3:])
        return pool_leaf.at[:, idx].set(blocks.astype(pool_leaf.dtype),
                                        mode="drop")

    with jax.named_scope("pool_scatter"):
        return jax.tree.map(s, data, gathered)


def touched_blocks(slot, n_tokens, max_nb: int, block_size: int):
    """(b, max_nb) bool: logical blocks covered by a write of ``n_tokens``
    rows starting at ``slot`` (both (b,) int32; n_tokens == 0 -> none)."""
    slot = jnp.asarray(slot, jnp.int32)
    n = jnp.asarray(n_tokens, jnp.int32)
    lo = slot // block_size
    hi = (slot + jnp.maximum(n, 1) - 1) // block_size
    ar = jnp.arange(max_nb, dtype=jnp.int32)[None]
    return (ar >= lo[:, None]) & (ar <= hi[:, None]) & (n > 0)[:, None]
