"""Paged KV pool for continuous batching.

The device-side store is literally ``model.init_cache(num_blocks,
block_size)``: the cache's BATCH axis becomes the physical-block axis and
its capacity axis the within-block slot axis.  Every leaf therefore keeps
the ``pos``-derived mask semantics of serving/cache.py (``pos == -1`` marks
an empty/invalid slot), so full, QUOKA-selected and baseline-selected
attention over gathered views all share the one position-mask code path.

A request's logical cache is the concatenation of its blocks in
block-table order, materialised per step by ``gather`` (block-table indexed
``jnp.take`` with out-of-range fill: table id -1 reads as an empty block)
and written back by ``scatter`` (table id -1 / untouched blocks drop).
Host-side bookkeeping (free-list, per-request tables) lives on
``PagedKVCache``; the gather/scatter functions are pure and live inside the
engine's jitted step functions.

Supported cache kinds: linear attention KV ("attn", "attn_moe", "enc-free
GQA) and MLA latent caches.  Recurrent states (mamba/rwkv) do not
block-decompose over time, whisper cross-KV is encoder-owned, and
sliding-window ring buffers wrap at the window rather than the block — all
three are rejected at pool construction.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

_UNSUPPORTED_KINDS = ("mamba", "mamba_shared_attn", "rwkv", "dec_cross",
                      "attn_local")


def blocks_for_request(prompt_len: int, max_new: int, chunk_size: int,
                       block_size: int) -> int:
    """Blocks reserved at admission (conservative: no mid-flight OOM).

    Prefill writes whole B_CP chunks (the ragged tail is right-padded with
    pos = -1 garbage that decode later overwrites), so the reservation
    covers max(chunk-padded prompt, prompt + max_new) slots."""
    padded = -(-prompt_len // chunk_size) * chunk_size
    span = max(padded, prompt_len + max_new)
    return -(-span // block_size)


class PagedKVCache:
    """Fixed-size-block KV pool + per-request block tables + free-list."""

    def __init__(self, model, num_blocks: int, block_size: int):
        kinds = [k for s in model.stacks for k in s.period]
        bad = sorted(set(k for k in kinds if k in _UNSUPPORTED_KINDS))
        if bad:
            raise ValueError(
                f"paged KV pool supports attention/MLA caches only; "
                f"model has unsupported block kinds {bad}")
        if model.cfg.family == "vlm":
            raise ValueError("paged KV pool does not support VLM frontends")
        self.model = model
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        self.data = model.init_cache(self.num_blocks, self.block_size)
        self._free: List[int] = list(range(self.num_blocks))
        self._tables: Dict[int, List[int]] = {}

    # ---- free-list bookkeeping ------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_allocated(self) -> int:
        return self.num_blocks - len(self._free)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, rid: int, n: int) -> List[int]:
        if rid in self._tables:
            raise RuntimeError(f"request {rid} already holds blocks")
        if n > len(self._free):
            raise RuntimeError(
                f"pool exhausted: need {n} blocks, {len(self._free)} free")
        blocks = [self._free.pop() for _ in range(n)]
        self._tables[rid] = blocks
        return blocks

    def free(self, rid: int) -> None:
        blocks = self._tables.pop(rid)   # KeyError on double free
        self._free.extend(blocks)

    def table(self, rid: int) -> List[int]:
        return self._tables[rid]

    def table_array(self, rids: Sequence[int], rows: int,
                    max_blocks: int) -> np.ndarray:
        """(rows, max_blocks) int32 block table, -1 padded (empty block).
        Rows beyond len(rids) are idle (all -1)."""
        tab = np.full((rows, max_blocks), -1, np.int32)
        for i, rid in enumerate(rids):
            blocks = self._tables[rid]
            tab[i, :len(blocks)] = blocks
        return tab

    def check_invariants(self) -> None:
        """No block leaked, none double-allocated, none double-freed."""
        allocated = [b for t in self._tables.values() for b in t]
        assert len(set(allocated)) == len(allocated), "block double-allocated"
        assert len(set(self._free)) == len(self._free), "block double-freed"
        assert sorted(allocated + self._free) == list(range(self.num_blocks)), \
            "block leaked or invented"


# ---------------------------------------------------------------------------
# pure gather/scatter (used inside the engine's jitted step functions)
# ---------------------------------------------------------------------------

def gather(data, table, num_blocks: int, block_size: int):
    """Materialise per-request linear caches from the pool.

    table: (b, max_nb) int32 physical block ids, -1 = empty.  Returns a
    cache pytree whose KV leaves are (R, b, max_nb * block_size, ...) — a
    standard linear cache view; empty blocks read as pos = -1 / zeros, so
    the position-mask machinery needs no special case."""
    b, nb = table.shape
    idx = jnp.where(table < 0, num_blocks, table).reshape(-1)

    def g(leaf):
        if leaf.ndim < 3:
            return leaf                          # enc_done & friends
        fill = -1 if jnp.issubdtype(leaf.dtype, jnp.integer) else 0
        out = jnp.take(leaf, idx, axis=1, mode="fill", fill_value=fill)
        return out.reshape(leaf.shape[0], b, nb * block_size,
                           *leaf.shape[3:])

    return jax.tree.map(g, data)


def scatter(data, gathered, table, touched, num_blocks: int,
            block_size: int):
    """Write gathered views back into the pool.

    ``touched`` (b, max_nb) bool limits the write to blocks the step
    actually modified; untouched and null (-1) table entries are mapped out
    of range and dropped."""
    b, nb = table.shape
    idx = jnp.where((table >= 0) & touched, table, num_blocks).reshape(-1)

    def s(pool_leaf, gath_leaf):
        if pool_leaf.ndim < 3:
            return pool_leaf
        blocks = gath_leaf.reshape(gath_leaf.shape[0], b * nb, block_size,
                                   *gath_leaf.shape[3:])
        return pool_leaf.at[:, idx].set(blocks.astype(pool_leaf.dtype),
                                        mode="drop")

    return jax.tree.map(s, data, gathered)


def touched_blocks(slot, n_tokens, max_nb: int, block_size: int):
    """(b, max_nb) bool: logical blocks covered by a write of ``n_tokens``
    rows starting at ``slot`` (both (b,) int32; n_tokens == 0 -> none)."""
    slot = jnp.asarray(slot, jnp.int32)
    n = jnp.asarray(n_tokens, jnp.int32)
    lo = slot // block_size
    hi = (slot + jnp.maximum(n, 1) - 1) // block_size
    ar = jnp.arange(max_nb, dtype=jnp.int32)[None]
    return (ar >= lo[:, None]) & (ar <= hi[:, None]) & (n > 0)[:, None]
