"""Paged KV pool for continuous batching, with cross-request prefix caching.

The device-side store is literally ``model.init_cache(num_blocks,
block_size)``: the cache's BATCH axis becomes the physical-block axis and
its capacity axis the within-block slot axis.  Every leaf therefore keeps
the ``pos``-derived mask semantics of serving/cache.py (``pos == -1`` marks
an empty/invalid slot), so full, QUOKA-selected and baseline-selected
attention over gathered views all share the one position-mask code path.

A request's logical cache is the concatenation of its blocks in
block-table order, materialised per step by ``gather`` (block-table indexed
``jnp.take`` with out-of-range fill: table id -1 reads as an empty block)
and written back by ``scatter`` (table id -1 / untouched blocks drop).
Host-side bookkeeping (free-list, per-request tables) lives on
``PagedKVCache``; the gather/scatter functions are pure and live inside the
engine's jitted step functions.

Prefix caching (multi-turn chats, shared system prompts):

  * FULL blocks of prompt KV are content-addressed by a rolling hash chain
    over their token ids (``h_i = hash(h_{i-1}, tokens_of_block_i)``, so a
    block's identity covers its whole prefix, not just its own tokens).
  * Blocks are REFCOUNTED: a cache-hit request pins a donor's prefix blocks
    into its own table read-only (the engine's scatter only ever writes
    blocks at/after the request's own prefill offset, so shared blocks are
    never written through a sharer's table).
  * When a block's refcount drops to zero it is not recycled immediately:
    registered (content-addressed) blocks move to an LRU list and stay
    resident — still matchable — until memory pressure evicts them into a
    fresh allocation.  Unregistered blocks are pos=-1-stamped and returned
    to the plain free list, so a recycled block can never leak a previous
    request's KV into a new allocation (stale ``pos`` values from a donor
    that sat at a *different* logical offset would otherwise look valid to
    the position masks).
  * Partially filled tail blocks (prompt_len % block_size != 0) are also
    registered, keyed by the hash of the full-block prefix they extend; a
    new request sharing the tail gets a COPY-ON-WRITE clone — the donor's
    block is copied into a privately owned block and the slots past the
    shared length are pos=-1-stamped — because the sharer must immediately
    write its own suffix into that block.

Supported cache kinds: linear attention KV ("attn", "attn_moe", enc-free
GQA) and MLA latent caches.  Recurrent states (mamba/rwkv) do not
block-decompose over time, whisper cross-KV is encoder-owned, and
sliding-window ring buffers wrap at the window rather than the block — all
three are rejected at pool construction.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_UNSUPPORTED_KINDS = ("mamba", "mamba_shared_attn", "rwkv", "dec_cross",
                      "attn_local")

# chain-hash seed for the empty prefix (any fixed int; tuples of ints hash
# deterministically, unaffected by PYTHONHASHSEED)
_HASH_SEED = 0x51554F4B


def blocks_for_request(prompt_len: int, max_new: int, chunk_size: int,
                       block_size: int, cached_len: int = 0) -> int:
    """Blocks reserved at admission (conservative: no mid-flight OOM).

    Prefill writes whole B_CP chunks (the ragged tail is right-padded with
    pos = -1 garbage that decode later overwrites), so the reservation
    covers max(chunk-padded prefill span, prompt + max_new) slots.  With a
    prefix-cache hit the prefill chunks start at ``cached_len``, so the
    chunk grid — and its padded span — shifts with the hit."""
    span = cached_len + -(-(prompt_len - cached_len) // chunk_size) * chunk_size
    span = max(span, prompt_len + max_new)
    return -(-span // block_size)


def max_blocks_bound(prompt_len: int, max_new: int, chunk_size: int,
                     block_size: int, align: int = 0) -> int:
    """Upper bound of ``blocks_for_request`` over every admissible
    ``cached_len`` (static jit geometry must cover the worst case).

    ``align`` is the prefix-hit granularity: when it is a multiple of the
    chunk size the chunk grid never shifts and the cold bound holds; token
    granularity (align=1, dense attention) can shift the last chunk to
    start at prompt_len - 1."""
    worst = 0 if (align and align % chunk_size == 0) \
        else max(0, prompt_len - 1)
    return max(blocks_for_request(prompt_len, max_new, chunk_size,
                                  block_size),
               blocks_for_request(prompt_len, max_new, chunk_size,
                                  block_size, cached_len=worst))


def _chain_hashes(tokens: np.ndarray, block_size: int) -> List[int]:
    """Rolling hash per FULL block: identity covers the whole prefix."""
    h, out = _HASH_SEED, []
    for i in range(len(tokens) // block_size):
        h = hash((h, tuple(map(int, tokens[i * block_size:
                                           (i + 1) * block_size]))))
        out.append(h)
    return out


class PagedKVCache:
    """Fixed-size-block KV pool + per-request block tables + free-list +
    content-addressed prefix cache (refcounts, LRU eviction, COW tails)."""

    def __init__(self, model, num_blocks: int, block_size: int, mesh=None):
        kinds = [k for s in model.stacks for k in s.period]
        bad = sorted(set(k for k in kinds if k in _UNSUPPORTED_KINDS))
        if bad:
            raise ValueError(
                f"paged KV pool supports attention/MLA caches only; "
                f"model has unsupported block kinds {bad}")
        if model.cfg.family == "vlm":
            raise ValueError("paged KV pool does not support VLM frontends")
        self.model = model
        self.mesh = mesh
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        self.data = model.init_cache(self.num_blocks, self.block_size)
        if mesh is not None:
            # blocks batch-shard over the FSDP axes (pool memory scales
            # with the data-parallel degree), heads over `model`; the
            # within-block slot axis is never split (sharding/specs.py
            # ``paged=True``) — a block is the atomic placement unit
            from repro.sharding import specs as sh
            self.data = jax.device_put(self.data, sh.to_shardings(
                mesh, sh.cache_specs(model.cfg, self.data, mesh,
                                     paged=True)))
        self._free: List[int] = list(range(self.num_blocks))
        self._tables: Dict[int, List[int]] = {}
        # ---- prefix cache state ----
        self._ref: Dict[int, int] = {}              # block -> live refcount
        self._lru: "OrderedDict[int, None]" = OrderedDict()  # evictable
        self._reg: Dict[int, Tuple] = {}            # block -> registration
        self._full: Dict[int, int] = {}             # chain hash -> block
        self._tail: Dict[int, int] = {}             # prefix hash -> block
        # ---- counters (Engine.stats / ServeResult.prefix) ----
        self.evictions = 0
        self.cow_copies = 0
        self.lookups = 0
        self.hit_requests = 0
        self.hit_tokens = 0
        self.prompt_tokens = 0
        self._stamp_fn = jax.jit(_stamp_blocks, donate_argnums=0)
        self._cow_fn = jax.jit(_cow_block, donate_argnums=0)

    # ---- free-list bookkeeping ------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_evictable(self) -> int:
        return len(self._lru)

    @property
    def num_cached(self) -> int:
        """Registered (matchable) blocks, live or evictable."""
        return len(self._reg)

    @property
    def num_allocated(self) -> int:
        return self.num_blocks - len(self._free) - len(self._lru)

    def can_alloc(self, n: int, exclude: Sequence[int] = ()) -> bool:
        """Can ``n`` FRESH blocks be produced (free list + LRU eviction),
        without evicting any block in ``exclude``?"""
        lru = len(self._lru) - sum(1 for b in exclude if b in self._lru)
        return n <= len(self._free) + lru

    def alloc(self, rid: int, n: int) -> List[int]:
        return self.alloc_prefix(rid, n)

    def alloc_prefix(self, rid: int, n_total: int,
                     shared: Sequence[int] = (),
                     cow: Optional[Tuple[int, int]] = None) -> List[int]:
        """Build request ``rid``'s table: ``shared`` (refcount-pinned prefix
        blocks, read-only, logical indices 0..len(shared)) followed by
        ``n_total - len(shared)`` fresh blocks.  ``cow = (src, keep)``
        initialises the first fresh block as a copy of block ``src`` with
        slots >= ``keep`` invalidated (shared partial tail)."""
        if rid in self._tables:
            raise RuntimeError(f"request {rid} already holds blocks")
        n_fresh = n_total - len(shared)
        protect = list(shared) + ([cow[0]] if cow else [])
        if not self.can_alloc(n_fresh, exclude=protect):
            raise RuntimeError(
                f"pool exhausted: need {n_fresh} fresh blocks, "
                f"{len(self._free)} free + {len(self._lru)} evictable")
        # pin the shared prefix FIRST so fresh allocation cannot evict it
        for b in shared:
            self._pin(b)
        fresh, stale = [], []
        for _ in range(n_fresh):
            b, was_cached = self._take_fresh(protect)
            if was_cached:
                stale.append(b)
            fresh.append(b)
            self._ref[b] = 1
        self._stamp(stale)                 # evicted content is stale
        if cow is not None:
            src, keep = cow
            if src not in self._ref and src not in self._lru:
                raise RuntimeError(f"COW source block {src} not resident")
            self.data = self._cow_fn(self.data, jnp.asarray(src, jnp.int32),
                                     jnp.asarray(fresh[0], jnp.int32),
                                     jnp.asarray(keep, jnp.int32))
            self.cow_copies += 1
        self._tables[rid] = list(shared) + fresh
        return self._tables[rid]

    def free(self, rid: int) -> None:
        """Release a request's blocks.  Registered blocks stay resident on
        the LRU list (matchable until evicted); the rest are pos=-1-stamped
        so no stale KV can leak into a later allocation."""
        blocks = self._tables.pop(rid)   # KeyError on double free
        stale = []
        for b in blocks:
            self._ref[b] -= 1
            if self._ref[b] == 0:
                del self._ref[b]
                if b in self._reg:
                    self._lru[b] = None          # MRU end, content kept
                else:
                    stale.append(b)
                    self._free.append(b)
        self._stamp(stale)

    def table(self, rid: int) -> List[int]:
        return self._tables[rid]

    def table_array(self, rids: Sequence[int], rows: int,
                    max_blocks: int) -> np.ndarray:
        """(rows, max_blocks) int32 block table, -1 padded (empty block).
        Rows beyond len(rids) are idle (all -1)."""
        tab = np.full((rows, max_blocks), -1, np.int32)
        for i, rid in enumerate(rids):
            blocks = self._tables[rid]
            tab[i, :len(blocks)] = blocks
        return tab

    # ---- prefix cache ----------------------------------------------------
    def match_prefix(self, tokens: np.ndarray,
                     chain: Optional[List[int]] = None
                     ) -> Tuple[List[int], Optional[Tuple[int, int]]]:
        """Longest cached prefix of ``tokens``: (matched full blocks, tail).
        ``tail = (block, n_common)`` if a registered partial tail extends
        the matched full-block prefix by ``n_common`` shared tokens.
        ``chain`` is the precomputed ``_chain_hashes`` of ``tokens`` — the
        scheduler caches it so a pool-blocked request re-matched every
        engine step doesn't re-hash its whole prompt each time."""
        toks = np.asarray(tokens).reshape(-1)
        bs = self.block_size
        if chain is None:
            chain = _chain_hashes(toks, bs)
        h, fulls = _HASH_SEED, []
        for h2 in chain:
            b = self._full.get(h2)
            if b is None:
                break
            fulls.append(b)
            h = h2
        tail = None
        tb = self._tail.get(h)
        if tb is not None:
            t_toks = self._reg[tb][2]
            rem = toks[len(fulls) * bs:]
            m = 0
            while m < min(len(rem), len(t_toks)) and \
                    int(rem[m]) == t_toks[m]:
                m += 1
            if m > 0:
                tail = (tb, m)
        return fulls, tail

    def register_prefix(self, rid: int, tokens: np.ndarray,
                        chain: Optional[List[int]] = None) -> None:
        """Content-address request ``rid``'s prompt blocks (call once the
        prompt is fully prefilled: full blocks are final; the partial tail's
        prompt slots are final — later decode tokens land past them)."""
        toks = np.asarray(tokens).reshape(-1)
        bs = self.block_size
        table = self._tables[rid]
        if chain is None:
            chain = _chain_hashes(toks, bs)
        h = _HASH_SEED
        for i, h2 in enumerate(chain):
            h = h2
            b = table[i]
            if b in self._reg or h in self._full:
                continue                 # shared / duplicate content
            self._reg[b] = ("full", h)
            self._full[h] = b
        rem = len(toks) % bs
        if rem:
            tb = table[len(toks) // bs]
            if tb not in self._reg and h not in self._tail:
                self._reg[tb] = ("tail", h,
                                 tuple(map(int, toks[len(toks) - rem:])))
                self._tail[h] = tb

    # ---- internals -------------------------------------------------------
    def _pin(self, b: int) -> None:
        """Refcount++ a resident block (pulling it off the LRU list)."""
        if b not in self._ref:
            if b not in self._lru:
                raise RuntimeError(f"block {b} not resident, cannot share")
            del self._lru[b]
            self._ref[b] = 1
        else:
            self._ref[b] += 1

    def _take_fresh(self, protect: Sequence[int]) -> Tuple[int, bool]:
        """One fresh block: free list first, then LRU eviction (oldest
        registered block loses its cache entry).  Returns (block, needs
        stamping) — free-list blocks were stamped when freed."""
        if self._free:
            return self._free.pop(), False
        for b in self._lru:                        # oldest first
            if b not in protect:
                del self._lru[b]
                self._unregister(b)
                self.evictions += 1
                return b, True
        raise RuntimeError("pool exhausted: no evictable block")

    def _unregister(self, b: int) -> None:
        reg = self._reg.pop(b)
        index = self._full if reg[0] == "full" else self._tail
        if index.get(reg[1]) == b:
            del index[reg[1]]

    def _stamp(self, blocks: List[int]) -> None:
        """pos=-1-stamp ``blocks`` on device: recycled blocks must read as
        empty (a donor's stale positions would pass the validity masks).
        The id vector is padded to the next power of two (not the pool
        size) so per-free device work is O(freed blocks) while the jit
        cache stays bounded to log2(num_blocks) shape variants."""
        if not blocks:
            return
        n = 1
        while n < len(blocks):
            n *= 2
        ids = np.full((min(n, self.num_blocks),), self.num_blocks, np.int32)
        ids[:len(blocks)] = blocks                 # rest drop out of range
        self.data = self._stamp_fn(self.data, jnp.asarray(ids))

    def check_invariants(self) -> None:
        """No block leaked, double-allocated, double-freed, or in two of
        {allocated, free, LRU}; refcounts match table membership; the hash
        indices and registrations agree."""
        refs: Dict[int, int] = {}
        for t in self._tables.values():
            assert len(set(t)) == len(t), "block twice in one table"
            for b in t:
                refs[b] = refs.get(b, 0) + 1
        assert refs == self._ref, "refcounts out of sync with tables"
        held = set(refs)
        free, lru = set(self._free), set(self._lru)
        assert len(self._free) == len(free), "block double-freed"
        assert not (held & free), "allocated block on the free list"
        assert not (held & lru), "allocated block on the LRU list"
        assert not (free & lru), "block both free and evictable"
        assert sorted(held | free | lru) == list(range(self.num_blocks)), \
            "block leaked or invented"
        for h, b in self._full.items():
            assert self._reg.get(b, (None, None))[:2] == ("full", h)
        for h, b in self._tail.items():
            r = self._reg.get(b)
            assert r is not None and r[0] == "tail" and r[1] == h
        for b in self._reg:
            assert b in held or b in lru, "registered block recycled"


# ---------------------------------------------------------------------------
# pure device helpers (jitted once per pool, donated data)
# ---------------------------------------------------------------------------

def _stamp_blocks(data, ids):
    """Set pos = -1 across blocks ``ids`` (padded with out-of-range ids,
    which drop).  Only integer leaves carry positions; KV payloads are left
    in place — the position masks make them unreadable."""
    def s(leaf):
        if leaf.ndim < 3 or not jnp.issubdtype(leaf.dtype, jnp.integer):
            return leaf
        upd = jnp.full((leaf.shape[0], ids.shape[0]) + leaf.shape[2:],
                       -1, leaf.dtype)
        return leaf.at[:, ids].set(upd, mode="drop")

    return jax.tree.map(s, data)


def _cow_block(data, src, dst, keep):
    """Copy block ``src`` into ``dst`` (copy-on-write of a shared partial
    tail), invalidating slots >= ``keep``: those hold the donor's private
    suffix/decode KV, which the sharer must not see."""
    def c(leaf):
        if leaf.ndim < 3:
            return leaf
        row = jnp.take(leaf, src, axis=1)          # (R, block_size, ...)
        if jnp.issubdtype(leaf.dtype, jnp.integer):
            slot = jnp.arange(leaf.shape[2], dtype=jnp.int32)
            valid = (slot < keep).reshape((1, -1) + (1,) * (row.ndim - 2))
            row = jnp.where(valid, row, -1)
        return leaf.at[:, dst].set(row)

    return jax.tree.map(c, data)


# ---------------------------------------------------------------------------
# pure gather/scatter (used inside the engine's jitted step functions)
# ---------------------------------------------------------------------------

def gather(data, table, num_blocks: int, block_size: int):
    """Materialise per-request linear caches from the pool.

    table: (b, max_nb) int32 physical block ids, -1 = empty.  Returns a
    cache pytree whose KV leaves are (R, b, max_nb * block_size, ...) — a
    standard linear cache view; empty blocks read as pos = -1 / zeros, so
    the position-mask machinery needs no special case."""
    b, nb = table.shape
    idx = jnp.where(table < 0, num_blocks, table).reshape(-1)

    def g(leaf):
        if leaf.ndim < 3:
            return leaf                          # enc_done & friends
        fill = -1 if jnp.issubdtype(leaf.dtype, jnp.integer) else 0
        out = jnp.take(leaf, idx, axis=1, mode="fill", fill_value=fill)
        return out.reshape(leaf.shape[0], b, nb * block_size,
                           *leaf.shape[3:])

    with jax.named_scope("pool_gather"):
        return jax.tree.map(g, data)


def gather_blocks(data, table, block_ids, num_blocks: int, block_size: int):
    """Materialise only SELECTED blocks of each request: a sub-view of
    ``gather`` driven by per-request logical block indices (b, nb_sel)
    int32, -1 = padding.

    This is the paged backing of core/plan.py's block-granular
    materialize: a plan built on the pool grid (granularity divides
    block_size) names whole logical blocks, so re-indexing the block
    TABLE — not the tokens — keeps the physical gather whole-block
    contiguous (one dynamic slice of ``block_size`` rows per selected
    block, never a per-token gather).  Padding ids read as pos = -1 /
    zeros, same as ``gather``."""
    sub = jnp.take_along_axis(table, jnp.maximum(block_ids, 0), axis=1)
    sub = jnp.where(block_ids >= 0, sub, -1)
    return gather(data, sub, num_blocks, block_size)


def scatter(data, gathered, table, touched, num_blocks: int,
            block_size: int):
    """Write gathered views back into the pool.

    ``touched`` (b, max_nb) bool limits the write to blocks the step
    actually modified; untouched and null (-1) table entries are mapped out
    of range and dropped.  Prefix-shared blocks are safe behind this mask:
    a sharer's writes start at its own prefill offset, so its touched
    window never covers the shared prefix."""
    b, nb = table.shape
    idx = jnp.where((table >= 0) & touched, table, num_blocks).reshape(-1)

    def s(pool_leaf, gath_leaf):
        if pool_leaf.ndim < 3:
            return pool_leaf
        blocks = gath_leaf.reshape(gath_leaf.shape[0], b * nb, block_size,
                                   *gath_leaf.shape[3:])
        return pool_leaf.at[:, idx].set(blocks.astype(pool_leaf.dtype),
                                        mode="drop")

    with jax.named_scope("pool_scatter"):
        return jax.tree.map(s, data, gathered)


def touched_blocks(slot, n_tokens, max_nb: int, block_size: int):
    """(b, max_nb) bool: logical blocks covered by a write of ``n_tokens``
    rows starting at ``slot`` (both (b,) int32; n_tokens == 0 -> none)."""
    slot = jnp.asarray(slot, jnp.int32)
    n = jnp.asarray(n_tokens, jnp.int32)
    lo = slot // block_size
    hi = (slot + jnp.maximum(n, 1) - 1) // block_size
    ar = jnp.arange(max_nb, dtype=jnp.int32)[None]
    return (ar >= lo[:, None]) & (ar <= hi[:, None]) & (n > 0)[:, None]
