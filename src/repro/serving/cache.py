"""Per-block runtime caches.

All caches are NamedTuples (pytree-friendly, scan-stackable).  KV slots carry
their absolute position (``pos``, -1 = empty); masks everywhere derive from
positions, so full caches, sliding-window ring buffers and QUOKA-selected
subsets share one mask code path (see core/attention.py).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class KVCache(NamedTuple):
    k: jax.Array      # (b, cap, n_kv, hd)
    v: jax.Array      # (b, cap, n_kv, hd)
    pos: jax.Array    # (b, cap) int32, -1 = empty

    @property
    def capacity(self) -> int:
        return self.k.shape[1]


def kv_init(batch: int, cap: int, n_kv: int, hd: int, dtype) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, cap, n_kv, hd), dtype),
        v=jnp.zeros((batch, cap, n_kv, hd), dtype),
        pos=jnp.full((batch, cap), -1, jnp.int32),
    )


def kv_write(cache: KVCache, k_new, v_new, start) -> KVCache:
    """Append a contiguous chunk at slot `start` (slot == absolute position
    for linear caches).  `start` may be a traced scalar."""
    b, t = k_new.shape[:2]
    pos_new = (start + jnp.arange(t, dtype=jnp.int32))[None, :].repeat(b, 0)
    z = jnp.zeros((), jnp.int32)
    return KVCache(
        k=jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype),
                                       (z, jnp.asarray(start, jnp.int32), z, z)),
        v=jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype),
                                       (z, jnp.asarray(start, jnp.int32), z, z)),
        pos=jax.lax.dynamic_update_slice(cache.pos, pos_new,
                                         (z, jnp.asarray(start, jnp.int32))),
    )


def kv_write_ring(cache: KVCache, k_new, v_new, start) -> KVCache:
    """Append modulo capacity (sliding-window ring buffer).  The chunk may
    wrap; a scatter over per-token slots handles it with static shapes."""
    b, t = k_new.shape[:2]
    cap = cache.capacity
    offs = jnp.arange(t, dtype=jnp.int32)
    slots = (jnp.asarray(start, jnp.int32) + offs) % cap          # (t,)
    pos_new = (jnp.asarray(start, jnp.int32) + offs)[None, :].repeat(b, 0)
    return KVCache(
        k=cache.k.at[:, slots].set(k_new.astype(cache.k.dtype)),
        v=cache.v.at[:, slots].set(v_new.astype(cache.v.dtype)),
        pos=cache.pos.at[:, slots].set(pos_new),
    )


class LatentCache(NamedTuple):
    """DeepSeek MLA compressed cache: per-token latent + shared rope key."""
    ckv: jax.Array    # (b, cap, kv_lora_rank)
    krope: jax.Array  # (b, cap, qk_rope_dim)
    pos: jax.Array    # (b, cap)

    @property
    def capacity(self) -> int:
        return self.ckv.shape[1]


def latent_init(batch: int, cap: int, r: int, rope: int, dtype) -> LatentCache:
    return LatentCache(
        ckv=jnp.zeros((batch, cap, r), dtype),
        krope=jnp.zeros((batch, cap, rope), dtype),
        pos=jnp.full((batch, cap), -1, jnp.int32),
    )


def latent_write(cache: LatentCache, ckv_new, krope_new, start) -> LatentCache:
    b, t = ckv_new.shape[:2]
    pos_new = (jnp.asarray(start, jnp.int32)
               + jnp.arange(t, dtype=jnp.int32))[None, :].repeat(b, 0)
    z = jnp.zeros((), jnp.int32)
    s = jnp.asarray(start, jnp.int32)
    return LatentCache(
        ckv=jax.lax.dynamic_update_slice(cache.ckv,
                                         ckv_new.astype(cache.ckv.dtype),
                                         (z, s, z)),
        krope=jax.lax.dynamic_update_slice(cache.krope,
                                           krope_new.astype(cache.krope.dtype),
                                           (z, s, z)),
        pos=jax.lax.dynamic_update_slice(cache.pos, pos_new, (z, s)),
    )


class MambaCache(NamedTuple):
    conv: jax.Array   # (b, d_conv - 1, conv_channels) trailing inputs
    ssd: jax.Array    # (b, n_heads, head_dim, d_state) fp32 state


class RWKVCache(NamedTuple):
    shift_tm: jax.Array  # (b, d) last token entering time-mix
    shift_cm: jax.Array  # (b, d) last token entering channel-mix
    wkv: jax.Array       # (b, n_heads, head_dim, head_dim) fp32 state


class CrossKV(NamedTuple):
    """Encoder-derived cross-attention KV (whisper); computed once."""
    k: jax.Array      # (b, n_ctx, n_kv, hd)
    v: jax.Array


class BlockCache(NamedTuple):
    """Union cache for one block; unused fields are () placeholders so the
    pytree structure stays uniform inside a scanned stack."""
    kv: object = ()
    latent: object = ()
    mamba: object = ()
    rwkv: object = ()
    cross: object = ()
