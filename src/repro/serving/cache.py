"""Per-block runtime caches.

All caches are NamedTuples (pytree-friendly, scan-stackable).  KV slots carry
their absolute position (``pos``, -1 = empty); masks everywhere derive from
positions, so full caches, sliding-window ring buffers and QUOKA-selected
subsets share one mask code path (see core/attention.py).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class KVCache(NamedTuple):
    k: jax.Array      # (b, cap, n_kv, hd)
    v: jax.Array      # (b, cap, n_kv, hd)
    pos: jax.Array    # (b, cap) int32, -1 = empty

    @property
    def capacity(self) -> int:
        return self.k.shape[1]


def kv_init(batch: int, cap: int, n_kv: int, hd: int, dtype) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, cap, n_kv, hd), dtype),
        v=jnp.zeros((batch, cap, n_kv, hd), dtype),
        pos=jnp.full((batch, cap), -1, jnp.int32),
    )


def _pos_rows(start, t: int, b: int) -> jax.Array:
    """Default stored positions for a chunk write: start + [0, t)."""
    s = jnp.asarray(start, jnp.int32)
    offs = jnp.arange(t, dtype=jnp.int32)
    if s.ndim == 0:
        return (s + offs)[None, :].repeat(b, 0)
    return s[:, None] + offs[None, :]


def kv_write(cache: KVCache, k_new, v_new, start, pos_new=None) -> KVCache:
    """Append a contiguous chunk at slot `start` (slot == absolute position
    for linear caches).  `start` may be a traced scalar or a per-row ``(b,)``
    vector (continuous batching: every request in the step batch writes at
    its own offset).  ``pos_new`` optionally overrides the stored positions
    with an explicit ``(b, t)`` array — pad slots marked ``-1`` there are
    invalid and mask themselves out of attention and selection scoring."""
    b, t = k_new.shape[:2]
    s = jnp.asarray(start, jnp.int32)
    pos_new = _pos_rows(s, t, b) if pos_new is None \
        else jnp.asarray(pos_new, jnp.int32)
    if s.ndim == 0:
        z = jnp.zeros((), jnp.int32)
        return KVCache(
            k=jax.lax.dynamic_update_slice(cache.k,
                                           k_new.astype(cache.k.dtype),
                                           (z, s, z, z)),
            v=jax.lax.dynamic_update_slice(cache.v,
                                           v_new.astype(cache.v.dtype),
                                           (z, s, z, z)),
            pos=jax.lax.dynamic_update_slice(cache.pos, pos_new, (z, s)),
        )

    def row(kb, vb, pb, kn, vn, pn, si):
        z = jnp.zeros((), jnp.int32)
        return (jax.lax.dynamic_update_slice(kb, kn.astype(kb.dtype),
                                             (si, z, z)),
                jax.lax.dynamic_update_slice(vb, vn.astype(vb.dtype),
                                             (si, z, z)),
                jax.lax.dynamic_update_slice(pb, pn, (si,)))

    k2, v2, p2 = jax.vmap(row)(cache.k, cache.v, cache.pos,
                               k_new, v_new, pos_new, s)
    return KVCache(k=k2, v=v2, pos=p2)


def kv_write_ring(cache: KVCache, k_new, v_new, start, pos_new=None) -> KVCache:
    """Append modulo capacity (sliding-window ring buffer).  The chunk may
    wrap; a scatter over per-token slots handles it with static shapes.
    ``start`` must be a (possibly traced) scalar — windowed layers are not
    part of the paged/continuous path."""
    b, t = k_new.shape[:2]
    cap = cache.capacity
    offs = jnp.arange(t, dtype=jnp.int32)
    slots = (jnp.asarray(start, jnp.int32) + offs) % cap          # (t,)
    pos_new = _pos_rows(start, t, b) if pos_new is None \
        else jnp.asarray(pos_new, jnp.int32)
    return KVCache(
        k=cache.k.at[:, slots].set(k_new.astype(cache.k.dtype)),
        v=cache.v.at[:, slots].set(v_new.astype(cache.v.dtype)),
        pos=cache.pos.at[:, slots].set(pos_new),
    )


class LatentCache(NamedTuple):
    """DeepSeek MLA compressed cache: per-token latent + shared rope key."""
    ckv: jax.Array    # (b, cap, kv_lora_rank)
    krope: jax.Array  # (b, cap, qk_rope_dim)
    pos: jax.Array    # (b, cap)

    @property
    def capacity(self) -> int:
        return self.ckv.shape[1]


def latent_init(batch: int, cap: int, r: int, rope: int, dtype) -> LatentCache:
    return LatentCache(
        ckv=jnp.zeros((batch, cap, r), dtype),
        krope=jnp.zeros((batch, cap, rope), dtype),
        pos=jnp.full((batch, cap), -1, jnp.int32),
    )


def latent_write(cache: LatentCache, ckv_new, krope_new, start,
                 pos_new=None) -> LatentCache:
    """MLA twin of ``kv_write``: same scalar-or-per-row ``start`` and
    optional explicit ``pos_new`` semantics."""
    b, t = ckv_new.shape[:2]
    s = jnp.asarray(start, jnp.int32)
    pos_new = _pos_rows(s, t, b) if pos_new is None \
        else jnp.asarray(pos_new, jnp.int32)
    if s.ndim == 0:
        z = jnp.zeros((), jnp.int32)
        return LatentCache(
            ckv=jax.lax.dynamic_update_slice(cache.ckv,
                                             ckv_new.astype(cache.ckv.dtype),
                                             (z, s, z)),
            krope=jax.lax.dynamic_update_slice(
                cache.krope, krope_new.astype(cache.krope.dtype), (z, s, z)),
            pos=jax.lax.dynamic_update_slice(cache.pos, pos_new, (z, s)),
        )

    def row(cb, rb, pb, cn, rn, pn, si):
        z = jnp.zeros((), jnp.int32)
        return (jax.lax.dynamic_update_slice(cb, cn.astype(cb.dtype), (si, z)),
                jax.lax.dynamic_update_slice(rb, rn.astype(rb.dtype), (si, z)),
                jax.lax.dynamic_update_slice(pb, pn, (si,)))

    c2, r2, p2 = jax.vmap(row)(cache.ckv, cache.krope, cache.pos,
                               ckv_new, krope_new, pos_new, s)
    return LatentCache(ckv=c2, krope=r2, pos=p2)


class MambaCache(NamedTuple):
    conv: jax.Array   # (b, d_conv - 1, conv_channels) trailing inputs
    ssd: jax.Array    # (b, n_heads, head_dim, d_state) fp32 state


class RWKVCache(NamedTuple):
    shift_tm: jax.Array  # (b, d) last token entering time-mix
    shift_cm: jax.Array  # (b, d) last token entering channel-mix
    wkv: jax.Array       # (b, n_heads, head_dim, head_dim) fp32 state


class CrossKV(NamedTuple):
    """Encoder-derived cross-attention KV (whisper); computed once."""
    k: jax.Array      # (b, n_ctx, n_kv, hd)
    v: jax.Array


class BlockCache(NamedTuple):
    """Union cache for one block; unused fields are () placeholders so the
    pytree structure stays uniform inside a scanned stack."""
    kv: object = ()
    latent: object = ()
    mamba: object = ()
    rwkv: object = ()
    cross: object = ()
