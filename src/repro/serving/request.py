"""Request lifecycle for the continuous-batching scheduler.

A request moves WAITING -> PREFILL -> DECODE -> DONE, with a SUSPENDED
detour when the policy preempts it:

  WAITING    queued; not yet admitted (pool capacity / batch-slot gated)
  PREFILL    admitted; its prompt is being consumed chunk-by-chunk (B_CP at
             a time, interleaved with other requests' chunks and decodes)
  DECODE     prompt fully prefilled; one token per engine decode step
  SUSPENDED  preempted mid-decode: its KV blocks were registered in the
             prefix cache and released (demoted to the host tier when one
             exists), its batch slot freed.  Re-admission matches the
             preserved KV (``resume_len`` covers any evicted suffix that
             must be replayed) and decoding continues where it stopped.
  DONE       finished on EOS / stop / length; its pool blocks are freed

SLO metadata (``tenant`` / ``priority`` / ``ttft_deadline_s``) is consumed
by serving/policy.py; the FCFS default ignores it.  All fields are
host-side bookkeeping (numpy / python) — device state lives in the paged
pool (serving/pool.py), addressed by the request's block table.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Union

import numpy as np

WAITING = "waiting"
PREFILL = "prefill"
DECODE = "decode"
SUSPENDED = "suspended"
DONE = "done"


@dataclass
class Request:
    rid: int
    tokens: np.ndarray              # (T,) int32 prompt
    max_new: int
    eos_id: Optional[int] = None    # stop token (None = length-only)
    arrival_s: float = 0.0          # arrival offset into the trace
    # ---- SLO metadata (serving/policy.py) ----
    tenant: str = "default"
    priority: int = 0               # higher = more important (ties only)
    ttft_deadline_s: Optional[float] = None   # TTFT SLO, relative to arrival
    # ---- runtime state (scheduler-owned) ----
    status: str = WAITING
    n_prefilled: int = 0            # prompt tokens consumed so far
    cached_len: int = 0             # prompt tokens served from the prefix
                                    # cache at admission (never recomputed)
    out: List[int] = field(default_factory=list)   # generated tokens
    ttft_s: Optional[float] = None
    done_s: Optional[float] = None
    preemptions: int = 0            # times suspended (policy decision)
    resume_len: int = 0             # >0 while resuming: prefill must reach
                                    # this many tokens of prompt+generated
                                    # KV before decoding continues

    @property
    def prompt_len(self) -> int:
        return int(self.tokens.shape[0])

    @property
    def decode_pos(self) -> int:
        """Cache slot / absolute position of the NEXT decode write: the
        last emitted token (not yet in the cache) goes at this position."""
        return self.prompt_len + len(self.out) - 1

    @property
    def kv_len(self) -> int:
        """Tokens whose KV the cache holds once prefill is complete and
        ``len(out)`` tokens are emitted: the prompt plus every generated
        token except the last (its KV is written by the NEXT decode step).
        This is what suspend must preserve and resume must restore."""
        return self.prompt_len + max(0, len(self.out) - 1)

    def seq_tokens(self) -> np.ndarray:
        """Prompt followed by the generated tokens (the full sequence the
        cache's KV corresponds to, one position per token)."""
        if not self.out:
            return self.tokens
        return np.concatenate(
            [self.tokens, np.asarray(self.out, np.int32)])

    @property
    def prefill_target(self) -> int:
        """Prefill finishes when ``n_prefilled`` reaches this: the prompt
        normally, the preserved-KV length when resuming from suspension."""
        return self.resume_len if self.resume_len else self.prompt_len

    def next_chunk(self, chunk_size: int):
        """(tokens (chunk_size,), start, valid_len) for the next prompt —
        or, when resuming, prompt+generated — chunk; the tail chunk is
        right-padded with zeros (pos = -1)."""
        src = self.seq_tokens() if self.resume_len else self.tokens
        start = self.n_prefilled
        vlen = min(chunk_size, self.prefill_target - start)
        buf = np.zeros((chunk_size,), np.int32)
        buf[:vlen] = src[start:start + vlen]
        return buf, start, vlen

    def finished(self) -> bool:
        if len(self.out) >= self.max_new:
            return True
        return (self.eos_id is not None and len(self.out) > 0
                and self.out[-1] == self.eos_id)


def _per_request(val, n: int, name: str) -> list:
    """Broadcast a scalar (or None) to n, or validate a length-n sequence."""
    if val is None or np.isscalar(val) or isinstance(val, (int, float, str)):
        return [val] * n
    val = list(val)
    if len(val) != n:
        raise ValueError(f"{name} has {len(val)} entries for {n} prompts")
    return val


def make_requests(prompts, max_new: Union[int, Sequence[int]], *,
                  eos_id=None, arrivals=None, tenants=None,
                  priorities=None, ttft_deadlines=None) -> List[Request]:
    """Convenience: one Request per 1-D prompt array.

    ``max_new`` / ``eos_id`` / ``tenants`` / ``priorities`` /
    ``ttft_deadlines`` may each be a scalar (shared by every request) or a
    per-request sequence — heterogeneous traces are what the multi-tenant
    SLO scenarios are made of."""
    n = len(prompts)
    arrivals = arrivals if arrivals is not None else [0.0] * n
    if len(arrivals) != n:
        raise ValueError(f"{len(arrivals)} arrivals for {n} prompts")
    max_news = _per_request(max_new, n, "max_new")
    eos_ids = _per_request(eos_id, n, "eos_id")
    tens = _per_request(tenants if tenants is not None else "default",
                        n, "tenants")
    prios = _per_request(priorities if priorities is not None else 0,
                         n, "priorities")
    dls = _per_request(ttft_deadlines, n, "ttft_deadlines")
    return [Request(rid=i, tokens=np.asarray(p, np.int32).reshape(-1),
                    max_new=int(m), eos_id=(None if e is None else int(e)),
                    arrival_s=float(a), tenant=str(t), priority=int(pr),
                    ttft_deadline_s=(None if d is None else float(d)))
            for i, (p, a, m, e, t, pr, d)
            in enumerate(zip(prompts, arrivals, max_news, eos_ids,
                             tens, prios, dls))]
