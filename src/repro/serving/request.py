"""Request lifecycle for the continuous-batching scheduler.

A request moves WAITING -> PREFILL -> DECODE -> DONE:

  WAITING  queued; not yet admitted (pool capacity / batch-slot gated)
  PREFILL  admitted; its prompt is being consumed chunk-by-chunk (B_CP at a
           time, interleaved with other requests' chunks and decodes)
  DECODE   prompt fully prefilled; one token per engine decode step
  DONE     finished on EOS / stop / length; its pool blocks are freed

All fields are host-side bookkeeping (numpy / python) — device state lives
in the paged pool (serving/pool.py), addressed by the request's block table.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

WAITING = "waiting"
PREFILL = "prefill"
DECODE = "decode"
DONE = "done"


@dataclass
class Request:
    rid: int
    tokens: np.ndarray              # (T,) int32 prompt
    max_new: int
    eos_id: Optional[int] = None    # stop token (None = length-only)
    arrival_s: float = 0.0          # arrival offset into the trace
    # ---- runtime state (scheduler-owned) ----
    status: str = WAITING
    n_prefilled: int = 0            # prompt tokens consumed so far
    cached_len: int = 0             # prompt tokens served from the prefix
                                    # cache at admission (never recomputed)
    out: List[int] = field(default_factory=list)   # generated tokens
    ttft_s: Optional[float] = None
    done_s: Optional[float] = None

    @property
    def prompt_len(self) -> int:
        return int(self.tokens.shape[0])

    @property
    def decode_pos(self) -> int:
        """Cache slot / absolute position of the NEXT decode write: the
        last emitted token (not yet in the cache) goes at this position."""
        return self.prompt_len + len(self.out) - 1

    def next_chunk(self, chunk_size: int):
        """(tokens (chunk_size,), start, valid_len) for the next prompt
        chunk; the tail chunk is right-padded with zeros (pos = -1)."""
        start = self.n_prefilled
        vlen = min(chunk_size, self.prompt_len - start)
        buf = np.zeros((chunk_size,), np.int32)
        buf[:vlen] = self.tokens[start:start + vlen]
        return buf, start, vlen

    def finished(self) -> bool:
        if len(self.out) >= self.max_new:
            return True
        return (self.eos_id is not None and len(self.out) > 0
                and self.out[-1] == self.eos_id)


def make_requests(prompts, max_new: int, *, eos_id: Optional[int] = None,
                  arrivals=None) -> List[Request]:
    """Convenience: one Request per 1-D prompt array."""
    arrivals = arrivals if arrivals is not None else [0.0] * len(prompts)
    return [Request(rid=i, tokens=np.asarray(p, np.int32).reshape(-1),
                    max_new=max_new, eos_id=eos_id, arrival_s=float(a))
            for i, (p, a) in enumerate(zip(prompts, arrivals))]
