"""gemma3-27b [dense] — 5:1 local:global attention, 128k context.

[hf:google/gemma-3-1b-pt family scaled per assignment]
62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144.
Five sliding-window (1024) layers per one global layer; QUOKA applies on
the global layers (local windows are already budget-bounded).
"""
from repro.configs.base import ModelConfig, QuokaConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b",
        family="dense",
        n_layers=62,
        d_model=5376,
        n_heads=32,
        n_kv_heads=16,
        head_dim=128,
        d_ff=21504,
        vocab=262144,
        sliding_window=1024,
        layer_pattern=("attn_local",) * 5 + ("attn",),
        rope_theta=1_000_000.0,
        max_seq_len=131_072,
        quoka=QuokaConfig(chunk_size=128, budget=2048, n_queries=16),
        source="hf:google/gemma-3-1b-pt",
    )
