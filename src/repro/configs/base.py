"""Config system for repro models.

Every architecture in ``src/repro/configs/<id>.py`` builds a ``ModelConfig``
via plain dataclasses.  Configs are immutable; reduced ("smoke") variants are
derived with ``dataclasses.replace`` through ``ModelConfig.smoke()``.

Block-type vocabulary (see models/stack.py):
  "attn"        dense GQA attention + SwiGLU MLP
  "attn_local"  sliding-window GQA attention + SwiGLU MLP
  "mla"         DeepSeek multi-head latent attention + SwiGLU MLP
  "mla_moe"     MLA attention + MoE FFN
  "attn_moe"    GQA attention + MoE FFN
  "rwkv"        RWKV6 time-mix + channel-mix
  "mamba"       Mamba2 (SSD) block
  "mamba_shared_attn"  Mamba2 block followed by the *shared* attention block
  "enc_attn"    bidirectional attention + MLP (whisper encoder)
  "dec_cross"   causal self-attn + cross-attn + MLP (whisper decoder)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int               # per-expert FFN hidden dim
    n_shared: int = 0           # shared (always-on) experts, deepseek-style
    router_aux_coef: float = 0.01
    capacity_factor: float = 1.25
    # "dense" = weighted sum over all experts (exact, smoke-test scale);
    # "capacity" = scatter/gather dispatch with fixed capacity (production).
    dispatch: str = "dense"


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_dim: int
    qk_rope_dim: int
    v_head_dim: int


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    d_conv: int = 4
    expand: int = 2             # d_inner = expand * d_model
    head_dim: int = 64          # mamba2 head dim


@dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64        # rank of the data-dependent decay LoRA


@dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style encoder over a (stubbed) conv/mel frontend."""
    n_layers: int
    n_ctx: int = 1500           # frames after conv frontend
    d_model: int = 0            # 0 -> same as decoder d_model
    n_heads: int = 0            # 0 -> same as decoder


@dataclass(frozen=True)
class FrontendConfig:
    """Stub modality frontend: input_specs() provides embeddings directly."""
    kind: str                   # "vision" | "audio"
    n_tokens: int               # patches / frames
    d_in: int                   # embedding dim produced by the stub


@dataclass(frozen=True)
class QuokaConfig:
    """Paper Algorithm 1/2 hyper-parameters."""
    chunk_size: int = 128          # B_CP
    budget: int = 1024             # B_SA
    # paper Table 2: B_SA as a fixed FRACTION of the context (25% there).
    # Under jit the budget must be static, so the ratio applies to the
    # cache capacity / prompt length rather than the running length.
    budget_ratio: Optional[float] = None
    n_queries: int = 16            # N_Q
    scoring: str = "cosine"        # "cosine" | "dot"   (Table 9 ablation)
    query_agg: str = "max"         # "max" | "mean"     (Table 10 ablation)
    # sink/local protection: always keep first `keep_first` and the current
    # chunk's own KV (the paper keeps the chunk KV by construction, eq. (2)).
    keep_first: int = 4
    method: str = "quoka"          # selection method (see core/selection.py)
    # kernel backend for the scoring + post-selection-attention hot path:
    # "auto" | "xla" | "pallas_interpret" | "pallas" — resolved by
    # kernels/ops.py::resolve_backend (env REPRO_BACKEND overrides "auto")
    backend: str = "auto"
    # method-specific knobs for the baselines
    rank: int = 64                 # SparQ / Loki down-projection dim
    lim_layers: int = 2            # LessIsMore: score every k-th layer
    # ---- SelectionPlan knobs (core/plan.py) ----
    # selection granularity in tokens: 1 = per-token top-k (the paper's
    # Algorithm 1), >1 = block-granular CompactAttention-style selection on
    # a fixed grid (set to the paged pool's block size so materialising a
    # plan is a contiguous block-table sub-view, serving/pool.py).
    granularity: int = 1
    # cross-layer plan reuse: re-score every `reuse_interval` layers and
    # reuse the previous layer's plan in between (LessIsMore-style depth
    # amortisation, now first-class).  1 = score every layer (exact).
    reuse_interval: int = 1
    # global layer indices that ALWAYS re-score, breaking a reuse run
    # (periodic correction layers)
    correction_layers: Tuple[int, ...] = ()
    # low-rank scoring: project pre-aggregated queries and keys to this
    # dimension before the fused scoring kernel (Loki-style; a cached
    # deterministic projection stands in for offline PCA).  0 = full-dim.
    score_proj_dim: int = 0
    # gather-free fused selected attention: route block-granular selection
    # (granularity > 1) onto kernels/selected_attention.py, which streams
    # each selected KV slab straight from the unmaterialized cache instead
    # of materialize + attend (core/plan.py::fused_route has the full
    # dispatch rules; token plans, sliding windows, MLA and active meshes
    # stay on the staged path).
    fused_select_attn: bool = False
    # hierarchical KV pool (serving/pool.py): capacity of the host-memory
    # tier behind the device pool, in blocks.  0 = single-level pool
    # (pressure-eviction destroys cache entries); > 0 = eviction demotes
    # registered prefix blocks to pinned host buffers, admission matches
    # both tiers and promotes host hits back into fresh device blocks.
    host_tier_blocks: int = 0
    # max host-tier blocks the engine stages (async H2D) per serve step
    # ahead of their promotion, ranked by the QUOKA selection-count oracle
    # (serving/engine.py::_prefetch); 0 disables prefetch (promotions
    # fall back to copy-at-alloc).
    prefetch_depth: int = 4


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    # positional encoding
    use_rope: bool = True
    rope_theta: float = 10_000.0
    # sliding-window / local-global structure
    sliding_window: Optional[int] = None
    # repeating block pattern; None -> ("attn",) * n_layers collapsed to one
    # period.  e.g. gemma3: ("attn_local",)*5 + ("attn",)
    layer_pattern: Optional[Tuple[str, ...]] = None
    # explicit ((period, n_repeats), ...) override for non-periodic stacks,
    # e.g. deepseek-v3: ((("mla",), 3), (("mla_moe",), 58))
    layer_groups: Optional[Tuple[Tuple[Tuple[str, ...], int], ...]] = None
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    encoder: Optional[EncoderConfig] = None
    frontend: Optional[FrontendConfig] = None
    mtp: bool = False              # deepseek multi-token-prediction head
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    act: str = "silu"              # mlp activation ("silu"|"gelu"|"relu2")
    dtype: str = "bfloat16"
    # citation for the assigned-architecture pool
    source: str = ""
    # ---- runtime ----
    quoka: QuokaConfig = field(default_factory=QuokaConfig)
    use_pallas: bool = False       # True on real TPU; CPU runs use XLA path
    remat: bool = False            # activation checkpointing in the stack
    max_seq_len: int = 131_072

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def pattern(self) -> Tuple[str, ...]:
        if self.layer_pattern is not None:
            return self.layer_pattern
        base = "attn_moe" if self.moe is not None else "attn"
        if self.mla is not None:
            base = "mla_moe" if self.moe is not None else "mla"
        return (base,)

    def stacks(self) -> Sequence[Tuple[Tuple[str, ...], int]]:
        """Partition n_layers into (period, n_repeats) groups.

        Returns a list of period-stacks; the tail (n_layers % len(period))
        becomes its own single-repeat stack.
        """
        if self.layer_groups is not None:
            assert sum(len(p) * r for p, r in self.layer_groups) == self.n_layers
            return list(self.layer_groups)
        pat = self.pattern
        p = len(pat)
        reps, rem = divmod(self.n_layers, p)
        out = []
        if reps:
            out.append((pat, reps))
        if rem:
            out.append((pat[:rem], 1))
        return out

    def smoke(self, **overrides) -> "ModelConfig":
        """Reduced variant of the same family for CPU smoke tests."""
        ch = dict(
            n_layers=min(self.n_layers, 2),
            d_model=min(self.d_model, 256),
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=min(self.d_ff, 512),
            vocab=min(self.vocab, 512),
            head_dim=64 if self.resolved_head_dim >= 64 else self.resolved_head_dim,
            max_seq_len=4096,
            dtype="float32",
        )
        if self.n_kv_heads == self.n_heads:     # keep MHA archs MHA
            ch["n_kv_heads"] = ch["n_heads"]
        if self.layer_groups is not None:
            kinds = tuple(dict.fromkeys(
                k for pd, _ in self.layer_groups for k in pd))
            pat = kinds[:2] if len(kinds) >= 2 else kinds * 2
            ch["layer_groups"] = None
            ch["layer_pattern"] = pat
            ch["n_layers"] = len(pat)
        elif self.layer_pattern is not None:
            pat = self.layer_pattern[-ch["n_layers"]:]
            # keep at least one of each distinct block type if possible
            kinds = tuple(dict.fromkeys(self.layer_pattern))
            if len(kinds) > 1 and len(set(pat)) < len(kinds):
                pat = kinds[: ch["n_layers"]]
            while len(pat) < ch["n_layers"]:
                pat = pat + (pat[-1],)
            ch["layer_pattern"] = pat
            ch["n_layers"] = len(pat)
        if self.moe is not None:
            ch["moe"] = dataclasses.replace(
                self.moe, n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                d_expert=min(self.moe.d_expert, 256), dispatch="dense")
        if self.mla is not None:
            ch["mla"] = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                                  qk_nope_dim=32, qk_rope_dim=16,
                                  v_head_dim=32)
            ch["head_dim"] = 0
        if self.ssm is not None:
            ch["ssm"] = dataclasses.replace(self.ssm, d_state=16, head_dim=32)
        if self.rwkv is not None:
            ch["rwkv"] = RWKVConfig(head_dim=32, decay_lora=16)
        if self.encoder is not None:
            ch["encoder"] = dataclasses.replace(
                self.encoder, n_layers=2, n_ctx=64)
        if self.frontend is not None:
            ch["frontend"] = dataclasses.replace(
                self.frontend, n_tokens=16, d_in=min(self.frontend.d_in, 128))
        if self.sliding_window is not None:
            ch["sliding_window"] = min(self.sliding_window, 64)
        ch["quoka"] = dataclasses.replace(
            self.quoka, chunk_size=16, budget=32, n_queries=4, keep_first=2)
        ch.update(overrides)
        return dataclasses.replace(self, **ch)

    def param_count(self) -> int:
        """Analytic parameter count (approximate, for roofline MODEL_FLOPS)."""
        d, hd = self.d_model, self.resolved_head_dim
        nl = self.n_layers
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        per = 0
        counts = {}
        for kind in self.pattern:
            counts[kind] = counts.get(kind, 0) + 1
        pat = self.pattern
        reps = self.n_layers // len(pat) if len(pat) <= self.n_layers else 1
        total = emb
        # count per block kind over the real layer list
        layers = []
        for period, r in self.stacks():
            layers += list(period) * r
        for kind in layers:
            p = 0
            if kind in ("attn", "attn_local", "attn_moe", "enc_attn"):
                p += d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            if kind == "dec_cross":
                p += 2 * (d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d)
            if kind in ("mla", "mla_moe"):
                m = self.mla
                qk = m.qk_nope_dim + m.qk_rope_dim
                p += d * m.q_lora_rank + m.q_lora_rank * self.n_heads * qk
                p += d * (m.kv_lora_rank + m.qk_rope_dim)
                p += m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_head_dim)
                p += self.n_heads * m.v_head_dim * d
            if kind in ("attn", "attn_local", "mla", "enc_attn", "dec_cross"):
                p += 3 * d * self.d_ff
            if kind in ("attn_moe", "mla_moe"):
                e = self.moe
                p += d * e.n_experts  # router
                p += e.n_experts * 3 * d * e.d_expert
                p += e.n_shared * 3 * d * (e.d_expert if self.mla else self.d_ff)
            if kind == "rwkv":
                p += 4 * d * d + d * self.d_ff * 2   # time-mix + channel-mix
            if kind in ("mamba", "mamba_shared_attn"):
                di = self.ssm.expand * d
                p += d * 2 * di + di * d + 2 * di * self.ssm.d_state
                if kind == "mamba_shared_attn":
                    pass  # shared block counted once below
            total += p
        if "mamba_shared_attn" in layers:
            total += d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
            total += 3 * d * self.d_ff
        return int(total)

    def active_param_count(self) -> int:
        """Params active per token (MoE uses top_k + shared experts only)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        full = self.param_count()
        n_moe_layers = sum(1 for pd, r in self.stacks() for k in pd * r
                           if k in ("attn_moe", "mla_moe"))
        inactive = n_moe_layers * (e.n_experts - e.top_k) * 3 * self.d_model * e.d_expert
        return int(full - inactive)


_REGISTRY = {}


def register(fn):
    """Decorator: register a zero-arg config factory under its module name."""
    name = fn.__module__.rsplit(".", 1)[-1].replace("_", "-")
    _REGISTRY[name] = fn
    return fn


def get_config(name: str) -> ModelConfig:
    from repro import configs as _c  # noqa: F401  (triggers registration)
    key = name.replace("_", "-").replace(".", "-")
    if key not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[key]()


def list_configs():
    from repro import configs as _c  # noqa: F401
    return sorted(_REGISTRY)
