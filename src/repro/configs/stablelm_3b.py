"""stablelm-3b [dense] — MHA (kv=32) decoder. [hf:stabilityai/stablelm-2-1_6b]

32L d_model=2560 32H (GQA kv=32) d_ff=6912 vocab=50304.
n_q == n_kv, so QUOKA's GQA pre-aggregation degenerates to the identity
(still exact) — a useful edge case.
"""
from repro.configs.base import ModelConfig, QuokaConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-3b",
        family="dense",
        n_layers=32,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        head_dim=80,
        d_ff=6912,
        vocab=50304,
        rope_theta=10_000.0,
        quoka=QuokaConfig(chunk_size=128, budget=1024, n_queries=16),
        source="hf:stabilityai/stablelm-2-1_6b",
    )
