"""granite-3-2b [dense] — GQA decoder.  [hf:ibm-granite/granite-3.0-2b-base]

40L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=49155.
"""
from repro.configs.base import ModelConfig, QuokaConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-2b",
        family="dense",
        n_layers=40,
        d_model=2048,
        n_heads=32,
        n_kv_heads=8,
        head_dim=64,
        d_ff=8192,
        vocab=49155,
        rope_theta=10_000.0,
        quoka=QuokaConfig(chunk_size=128, budget=1024, n_queries=16),
        source="hf:ibm-granite/granite-3.0-2b-base",
    )
