"""rwkv6-1.6b [ssm] — Finch: attention-free, data-dependent decay.
[arXiv:2404.05892]

24L d_model=2048 d_ff=7168 vocab=65536.  No attention, no KV cache —
QUOKA is INAPPLICABLE (see DESIGN.md §Arch-applicability); the arch runs
with its native recurrent state.  head_dim 64 -> 32 wkv heads.
"""
from repro.configs.base import ModelConfig, QuokaConfig, RWKVConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b",
        family="ssm",
        n_layers=24,
        d_model=2048,
        n_heads=32,            # wkv heads = d_model / rwkv.head_dim
        n_kv_heads=32,
        d_ff=7168,
        vocab=65536,
        layer_pattern=("rwkv",),
        rwkv=RWKVConfig(head_dim=64, decay_lora=64),
        use_rope=False,
        act="relu2",
        quoka=QuokaConfig(chunk_size=128, budget=1024, n_queries=16),
        source="arXiv:2404.05892",
    )
