"""h2o-danube-3-4b [dense] — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818]

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000; mistral-style SWA
(window 4096) on all layers.  QUOKA still applies inside the window when
B_SA < window (budget 1024 < 4096).
"""
from repro.configs.base import ModelConfig, QuokaConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b",
        family="dense",
        n_layers=24,
        d_model=3840,
        n_heads=32,
        n_kv_heads=8,
        head_dim=120,
        d_ff=10240,
        vocab=32000,
        sliding_window=4096,
        layer_pattern=("attn_local",),
        rope_theta=10_000.0,
        quoka=QuokaConfig(chunk_size=128, budget=1024, n_queries=16),
        source="arXiv:2401.16818",
    )
