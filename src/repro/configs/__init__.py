"""Architecture configs (assigned pool + the paper's own models).

Importing this package registers every config module; access them through
``repro.configs.get_config(name)`` / ``list_configs()``.
"""
from repro.configs.base import (EncoderConfig, FrontendConfig, MLAConfig,
                                ModelConfig, MoEConfig, QuokaConfig,
                                RWKVConfig, SSMConfig, get_config,
                                list_configs, register)

# assigned-pool architectures -------------------------------------------------
from repro.configs import gemma3_27b        # noqa: F401
from repro.configs import granite_3_2b      # noqa: F401
from repro.configs import deepseek_v3_671b  # noqa: F401
from repro.configs import stablelm_3b       # noqa: F401
from repro.configs import internvl2_1b      # noqa: F401
from repro.configs import whisper_small     # noqa: F401
from repro.configs import rwkv6_1_6b        # noqa: F401
from repro.configs import olmoe_1b_7b       # noqa: F401
from repro.configs import h2o_danube_3_4b   # noqa: F401
from repro.configs import zamba2_7b         # noqa: F401
# the paper's own evaluation models -------------------------------------------
from repro.configs import llama3_2_3b       # noqa: F401
from repro.configs import qwen3_4b          # noqa: F401

ASSIGNED = (
    "gemma3-27b", "granite-3-2b", "deepseek-v3-671b", "stablelm-3b",
    "internvl2-1b", "whisper-small", "rwkv6-1.6b", "olmoe-1b-7b",
    "h2o-danube-3-4b", "zamba2-7b",
)

__all__ = [
    "ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig", "RWKVConfig",
    "EncoderConfig", "FrontendConfig", "QuokaConfig",
    "get_config", "list_configs", "register", "ASSIGNED",
]
