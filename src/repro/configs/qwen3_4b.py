"""qwen3-4b — the paper's latency-evaluation model (Yang et al. 2025).

Not part of the assigned pool; included because the paper's TTFT/latency
figures (Fig 5, 6) use it.  36L d_model=2560 32H (GQA kv=8) d_ff=9728
vocab=151936.
"""
from repro.configs.base import ModelConfig, QuokaConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b",
        family="dense",
        n_layers=36,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=9728,
        vocab=151936,
        rope_theta=1_000_000.0,
        quoka=QuokaConfig(chunk_size=128, budget=1024, n_queries=16),
        source="arXiv:2505.09388",
    )
