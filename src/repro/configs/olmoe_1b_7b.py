"""olmoe-1b-7b [moe] — 64 experts, top-8.  [arXiv:2409.02060]

16L d_model=2048 16H (GQA kv=16) expert d_ff=1024 vocab=50304.
"""
from repro.configs.base import ModelConfig, MoEConfig, QuokaConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b",
        family="moe",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=1024,
        vocab=50304,
        layer_pattern=("attn_moe",),
        moe=MoEConfig(n_experts=64, top_k=8, d_expert=1024,
                      dispatch="capacity"),
        rope_theta=10_000.0,
        quoka=QuokaConfig(chunk_size=128, budget=1024, n_queries=16),
        source="arXiv:2409.02060",
    )
