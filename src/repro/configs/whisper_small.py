"""whisper-small [audio] — encoder-decoder; conv/mel frontend STUBBED.
[arXiv:2212.04356]

12L (decoder) d_model=768 12H (kv=12, MHA) d_ff=3072 vocab=51865; 12-layer
encoder over 1500 stub frame embeddings.  Sinusoidal positions (NoPE w.r.t.
rope).  QUOKA applies to decoder self-attention; cross-attention scoring is
non-causal; the encoder is single-pass bidirectional (no cache).
"""
from repro.configs.base import (EncoderConfig, FrontendConfig, ModelConfig,
                                QuokaConfig, register)


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small",
        family="audio",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        head_dim=64,
        d_ff=3072,
        vocab=51865,
        layer_pattern=("dec_cross",),
        encoder=EncoderConfig(n_layers=12, n_ctx=1500),
        frontend=FrontendConfig(kind="audio", n_tokens=1500, d_in=768),
        use_rope=False,
        act="gelu",
        tie_embeddings=True,
        quoka=QuokaConfig(chunk_size=128, budget=512, n_queries=16),
        source="arXiv:2212.04356",
    )
