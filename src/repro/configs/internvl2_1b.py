"""internvl2-1b [vlm] — InternViT (stub) + InternLM2 decoder. [arXiv:2404.16821]

24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.
The vision frontend is a STUB per assignment: input_specs() provides
precomputed patch embeddings (256 tokens, d=1024); the in-model projector
(2-layer MLP) maps them into the LM embedding space.
"""
from repro.configs.base import (FrontendConfig, ModelConfig, QuokaConfig,
                                register)


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-1b",
        family="vlm",
        n_layers=24,
        d_model=896,
        n_heads=14,
        n_kv_heads=2,
        head_dim=64,
        d_ff=4864,
        vocab=151655,
        frontend=FrontendConfig(kind="vision", n_tokens=256, d_in=1024),
        rope_theta=1_000_000.0,
        quoka=QuokaConfig(chunk_size=128, budget=1024, n_queries=16),
        source="arXiv:2404.16821",
    )
