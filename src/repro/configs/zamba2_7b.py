"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention block.
[arXiv:2411.15242]

81L d_model=3584 32H (GQA kv=32) d_ff=14336 ssm_state=64.  Every 6th layer
additionally applies the single SHARED attention+MLP block (weight sharing
falls out of scanning with the shared params closed over).  QUOKA applies
to the shared attention block's KV cache; Mamba2 blocks are attention-free.
"""
from repro.configs.base import ModelConfig, QuokaConfig, SSMConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b",
        family="hybrid",
        n_layers=81,
        d_model=3584,
        n_heads=32,
        n_kv_heads=32,
        head_dim=112,
        d_ff=14336,
        vocab=32000,
        layer_pattern=("mamba",) * 5 + ("mamba_shared_attn",),
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64),
        rope_theta=10_000.0,
        quoka=QuokaConfig(chunk_size=128, budget=1024, n_queries=16),
        source="arXiv:2411.15242",
    )
