"""deepseek-v3-671b [moe] — MLA + 256-expert top-8 MoE + MTP. [arXiv:2412.19437]

61L d_model=7168 128H (MLA; assignment lists kv=128) expert d_ff=2048
vocab=129280.  First 3 layers use a dense FFN (18432, per the paper),
remaining 58 layers use 1 shared + 256 routed experts, top-8.
MLA: q_lora 1536, kv_lora 512, qk_nope 128, qk_rope 64, v 128 — the KV cache
stores only the 512-d compressed latent + 64-d rope key per token.
"""
from repro.configs.base import (MLAConfig, ModelConfig, MoEConfig,
                                QuokaConfig, register)


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=128,
        n_kv_heads=128,
        d_ff=18432,                       # dense layers' FFN
        vocab=129280,
        layer_groups=((("mla",), 3), (("mla_moe",), 58)),
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                      qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
        moe=MoEConfig(n_experts=256, top_k=8, d_expert=2048, n_shared=1,
                      dispatch="capacity"),
        mtp=True,
        rope_theta=10_000.0,
        tie_embeddings=False,
        quoka=QuokaConfig(chunk_size=128, budget=1024, n_queries=16),
        source="arXiv:2412.19437",
    )
