"""llama3.2-3b — the paper's primary evaluation model (Dubey et al. 2024).

Not part of the assigned pool; included because QUOKA's own experiments
(Tables 1,3; Figures 2,4) use it.  28L d_model=3072 24H (GQA kv=8)
d_ff=8192 vocab=128256.
"""
from repro.configs.base import ModelConfig, QuokaConfig, register


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="llama3-2-3b",
        family="dense",
        n_layers=28,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab=128256,
        rope_theta=500_000.0,
        quoka=QuokaConfig(chunk_size=128, budget=1024, n_queries=16),
        source="arXiv:2407.21783",
    )
