"""Synthetic data: (a) an LM stream with induction structure (trainable
signal), (b) a needle-in-a-haystack retrieval task (the NIAH/RULER proxy for
EXPERIMENTS.md §Claims), and (c) structured Q/K/V generators reproducing the
query-key geometry the paper observes (Figure 2) for the attention-level
accuracy benchmarks.

Token map for (a)/(b):  0 PAD · 1 NEEDLE · 2 QUERY · [3, 3+n_keys) key ids ·
[3+n_keys, 3+2·n_keys) value ids · rest filler.
"""
from __future__ import annotations

from typing import Dict, Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PAD, NEEDLE, QUERY = 0, 1, 2


# ---------------------------------------------------------------------------
# (a) LM stream with copy/induction structure
# ---------------------------------------------------------------------------

def lm_batches(key, vocab: int, batch: int, seq: int,
               repeat_frac: float = 0.3) -> Iterator[Dict]:
    """Infinite stream: random tokens where the 2nd half repeats spans of the
    1st half with prob `repeat_frac` — learnable induction signal."""
    rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 2**31 - 1)))
    while True:
        toks = rng.integers(3, vocab, size=(batch, seq))
        half = seq // 2
        for b in range(batch):
            if rng.random() < repeat_frac and half > 8:
                span = rng.integers(4, min(64, half))
                src = rng.integers(0, half - span)
                dst = rng.integers(half, seq - span)
                toks[b, dst:dst + span] = toks[b, src:src + span]
        yield {"tokens": jnp.asarray(toks, jnp.int32)}


# ---------------------------------------------------------------------------
# (b) needle retrieval (NIAH proxy)
# ---------------------------------------------------------------------------

def needle_batch(rng: np.random.Generator, vocab: int, batch: int, seq: int,
                 n_keys: int = 32, depth: float | None = None,
                 n_distractors: int = 0) -> Dict:
    """[filler... NEEDLE k v ... QUERY k v] — the model must emit v after
    (QUERY, k).  `n_distractors` extra (NEEDLE k' v') pairs with DIFFERENT
    keys are inserted (RULER multi-key style): the model must retrieve the
    right one, and a KV selector must keep several critical regions alive.
    loss_mask marks only the answer position; `depth` pins the true needle."""
    assert vocab >= 3 + 2 * n_keys + 8
    assert n_distractors + 1 <= n_keys
    filler_lo = 3 + 2 * n_keys
    toks = rng.integers(filler_lo, vocab, size=(batch, seq))
    mask = np.zeros((batch, seq), np.float32)
    for b in range(batch):
        kids = rng.permutation(n_keys)[: n_distractors + 1]
        lo, hi = 1, seq - 6
        spots = rng.permutation(np.arange(lo, hi - 3, 4))[: n_distractors + 1]
        # the TRUE needle goes to the depth-pinned spot (index 0)
        if depth is not None:
            spots[0] = int(lo + (hi - lo) * depth)
        for kid, pos in zip(kids, spots):
            k_tok, v_tok = 3 + int(kid), 3 + n_keys + int(kid)
            toks[b, pos:pos + 3] = [NEEDLE, k_tok, v_tok]
        k0, v0 = 3 + int(kids[0]), 3 + n_keys + int(kids[0])
        toks[b, -3:] = [QUERY, k0, v0]
        mask[b, -1] = 1.0          # predict v at the last position
    return {"tokens": jnp.asarray(toks, jnp.int32),
            "loss_mask": jnp.asarray(mask)}


def needle_batches(key, vocab: int, batch: int, seq: int,
                   n_keys: int = 32, n_distractors: int = 0) -> Iterator[Dict]:
    rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 2**31 - 1)))
    while True:
        yield needle_batch(rng, vocab, batch, seq, n_keys,
                           n_distractors=n_distractors)


def needle_accuracy(model, params, batch: Dict, method: str,
                    chunk_pad: int = 128) -> float:
    """Retrieval accuracy: run chunked prefill over tokens[:-1] with the given
    selection method and check argmax == the needle value."""
    tok = batch["tokens"]
    b, t = tok.shape
    tp = (t - 1) - ((t - 1) % min(chunk_pad, model.cfg.quoka.chunk_size))
    prompt = tok[:, (t - 1) - tp: t - 1]
    target = tok[:, -1]
    cache = model.init_cache(b, tp + 8)
    logits, _ = model.prefill(params, {"tokens": prompt}, cache, method)
    return float(jnp.mean((jnp.argmax(logits, -1) == target)))


# ---------------------------------------------------------------------------
# (c) structured Q/K/V reproducing the paper's Figure-2 geometry
# ---------------------------------------------------------------------------

def structured_qkv(key, b: int, t: int, h: int, n_kv: int, d: int,
                   outlier_frac: float = 0.08, n_needles: int = 24,
                   n_sinks: int = 4, sharp: float = 8.0
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Q/K geometry mirroring the paper's Figure 2:

      * BULK queries cluster tightly around the mean query and concentrate
        their attention on a small SHARED set of sink keys (first positions)
        — "near-mean queries concentrate on a small shared group of keys";
      * a few OUTLIER queries (low CosSim to the mean — high S_q) align
        sharply with specific NEEDLE keys scattered in the context —
        "higher S_q correlates with larger max_k(A)" (Fig 2c);
      * the key cluster has negative cosine with the mean query (Fig 2b);
      * needle positions carry DISTINCTIVE (large-norm) values: retrieving
        them matters for the output, as in real retrieval heads — an evicted
        needle is an O(1) output error, not noise.

    Scales are set so the geometry holds at the SOFTMAX level, not just in
    cosine space: concentration requires the sink/needle logit to clear the
    diffuse cluster by ~log(t) (≈6 for t=512), otherwise every query's mass
    is spread over the whole cluster and mean-mass selection is trivially
    L2-optimal — the regime the paper's Figure 2 explicitly contrasts with.

    Mean/uniform aggregation washes the outliers out; QUOKA's
    dissimilar-query subselection keeps them.  Returns q (b,t,h,d),
    k (b,t,n_kv,d), v (b,t,n_kv,d).
    """
    ks = jax.random.split(key, 9)
    dk = jax.random.normal(ks[0], (d,))
    dk = dk / jnp.linalg.norm(dk)
    dq = -dk                                   # bulk query direction
    # keys: anisotropic cluster along +dk (negative cosine with M_Q)
    k_noise = jax.random.normal(ks[1], (b, t, n_kv, d)) * 0.5
    k = dk * 1.5 + k_noise
    # sinks: aligned WITH the bulk queries, with enough norm that near-mean
    # queries CONCENTRATE on them (logit gap > log t over the cluster)
    sink = (jnp.arange(t) < n_sinks)[None, :, None, None]
    k = jnp.where(sink, dq * 16.0 + k_noise * 0.2, k)
    # needles: distinct off-cluster directions at fixed scattered positions
    needle_pos = jnp.asarray(
        np.linspace(n_sinks + 3, t - 8, n_needles).astype(np.int32))
    needle_dirs = jax.random.normal(ks[2], (n_needles, d))
    needle_dirs = needle_dirs / jnp.linalg.norm(needle_dirs, axis=-1,
                                                keepdims=True)
    is_needle = jnp.zeros((t,), bool).at[needle_pos].set(True)
    ndir_full = jnp.zeros((t, d)).at[needle_pos].set(needle_dirs * 4.0)
    k = jnp.where(is_needle[None, :, None, None],
                  ndir_full[None, :, None, :] + k_noise * 0.2, k)
    v = jax.random.normal(ks[4], (b, t, n_kv, d))
    # needle values are distinctive: missing one costs O(1) output error
    v = jnp.where(is_needle[None, :, None, None], v * 3.0, v)
    # bulk queries: tight cluster along dq
    q_noise = jax.random.normal(ks[5], (b, t, h, d)) * 0.3
    q = dq * 2.5 + q_noise
    # outlier queries: sharply aligned with a random NEEDLE key.  Outlier-ness
    # and the target are TOKEN-level (shared across heads) — heads inside a
    # GQA group look at the same retrieved token, which is exactly why the
    # paper's group-mean pre-aggregation is accurate (Bhojanapalli et al.).
    is_out = jax.random.bernoulli(ks[6], outlier_frac, (b, t, 1, 1))
    tgt = jnp.take(needle_pos,
                   jax.random.randint(ks[7], (b, t), 0, n_needles))
    kq = jnp.take_along_axis(
        jnp.broadcast_to(k.mean(axis=2)[:, :, None, :], (b, t, h, d)),
        jnp.broadcast_to(tgt[..., None, None], (b, t, h, d)), axis=1)
    # outliers share the bulk queries' NORM (activations are norm-bounded in
    # real models) — direction carries the retrieval signal, which is why the
    # paper's cosine scoring beats the scale-sensitive dot product
    kq_dir = kq / (jnp.linalg.norm(kq, axis=-1, keepdims=True) + 1e-8)
    bulk_norm = jnp.linalg.norm(q, axis=-1, keepdims=True)
    q = jnp.where(is_out, kq_dir * bulk_norm * (sharp / 3.0) + q_noise, q)
    return q, k, v
